"""The four concurrency rules (see the package docstring for the catalog).

Interprocedural reasoning is name-based and deliberately conservative:
``self.foo()`` resolves within the class (then its scanned bases);
``obj.foo()`` resolves only when exactly one scanned class defines
``foo``; anything else is opaque.  Resolved callees contribute their
transitive lock acquisitions and blocking calls to the caller's context
(cycle-guarded memoized closure), which is what catches "holds the stripe
locks, calls three functions down, and *that* one sleeps".
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from . import lockspec
from .report import Finding
from .scanner import (AcquireEvent, CallEvent, ClassInfo, FuncSummary,
                      LockTok, ModuleSummary)

BLOCKING_EXACT = {
    "os.pwrite", "os.pread", "os.preadv", "os.pwritev",
    "os.fsync", "os.fdatasync", "os.replace", "time.sleep", "open",
}
BLOCKING_METHODS = {"submit", "result", "join", "shutdown", "wait"}
_CONDITION_HINT = ("_cond", "_idle")

MUTATOR_METHODS = {
    "append", "extend", "insert", "update", "setdefault", "pop", "popitem",
    "remove", "discard", "clear", "sort", "reverse", "add", "appendleft",
}
IMPURE_ROOTS = {"os", "time", "random", "uuid", "socket"}
KV_COMPONENTS = {"kv", "_kv", "txn", "_txn", "client", "_client"}


# ------------------------------------------------------------ indexing

@dataclass
class Index:
    exact: Dict[Tuple[str, Optional[str], str], FuncSummary]
    by_method: Dict[str, List[FuncSummary]]
    classes: Dict[str, List[ClassInfo]]

    @classmethod
    def build(cls, mods: Sequence[ModuleSummary]) -> "Index":
        exact: Dict[Tuple[str, Optional[str], str], FuncSummary] = {}
        by_method: Dict[str, List[FuncSummary]] = {}
        classes: Dict[str, List[ClassInfo]] = {}
        for m in mods:
            for f in m.functions:
                exact[(f.module, f.cls, f.name)] = f
                by_method.setdefault(f.name, []).append(f)
            for c in m.classes.values():
                classes.setdefault(c.name, []).append(c)
        return cls(exact, by_method, classes)

    def resolve(self, chain: str, ctx: FuncSummary) -> Optional[FuncSummary]:
        if "()" in chain or "[]" in chain:
            return None
        parts = chain.split(".")
        if len(parts) == 1:
            name = parts[0]
            hit = self.exact.get((ctx.module, None, name))
            if hit is not None:
                return hit
            # class constructor in the same module
            for ci in self.classes.get(name, []):
                if ci.module == ctx.module:
                    return self.exact.get((ci.module, name, "__init__"))
            return None
        if len(parts) == 2 and parts[0] == "self" and ctx.cls is not None:
            hit = self.exact.get((ctx.module, ctx.cls, parts[1]))
            if hit is not None:
                return hit
            for ci in self.classes.get(ctx.cls, []):
                if ci.module != ctx.module:
                    continue
                for base in ci.bases:
                    for bi in self.classes.get(base, []):
                        hit = self.exact.get((bi.module, base, parts[1]))
                        if hit is not None:
                            return hit
            return None
        if len(parts) == 2:
            cands = [f for f in self.by_method.get(parts[1], [])
                     if f.cls is not None]
            if len(cands) == 1:
                return cands[0]
        return None


# ------------------------------------------------------- blocking calls

def _is_condition_wait(chain: str, ctx: FuncSummary,
                       index: Index) -> bool:
    parts = chain.split(".")
    if parts[-1] != "wait" or len(parts) < 2:
        return False
    attr = parts[-2]
    if any(h in attr for h in _CONDITION_HINT):
        return True
    if ctx.cls is not None:
        for ci in index.classes.get(ctx.cls, []):
            if ci.module == ctx.module and \
                    ci.lock_attrs.get(attr) == "condition":
                return True
    return False


def _is_blocking(chain: str, ctx: FuncSummary, index: Index) -> bool:
    if chain in BLOCKING_EXACT:
        return True
    if chain.startswith("os.path."):
        return False        # path arithmetic, not I/O ('join' collides)
    leaf = chain.split(".")[-1]
    if leaf in BLOCKING_METHODS and "." in chain:
        if leaf == "wait" and _is_condition_wait(chain, ctx, index):
            return False
        return True
    return False


# -------------------------------------------------- transitive effects

@dataclass
class Effects:
    acquires: List[Tuple[AcquireEvent, FuncSummary]] = field(
        default_factory=list)
    blocking: List[Tuple[CallEvent, FuncSummary]] = field(
        default_factory=list)


def _effects(fn: FuncSummary, index: Index,
             memo: Dict[str, Effects],
             stack: Set[str]) -> Effects:
    key = f"{fn.path}:{fn.qualname}"
    if key in memo:
        return memo[key]
    if key in stack:
        return Effects()
    stack.add(key)
    eff = Effects()
    eff.acquires.extend((a, fn) for a in fn.acquires)
    for c in fn.calls:
        if _is_blocking(c.chain, fn, index):
            eff.blocking.append((c, fn))
            continue
        callee = index.resolve(c.chain, fn)
        if callee is not None and callee is not fn:
            sub = _effects(callee, index, memo, stack)
            eff.acquires.extend(sub.acquires)
            eff.blocking.extend(sub.blocking)
    stack.discard(key)
    memo[key] = eff
    return eff


# --------------------------------------------------------------- WTF001

def _check_acquire(tok: LockTok, held: Tuple[LockTok, ...], kind: str,
                   in_loop: bool, loop_sorted: bool, fn: FuncSummary,
                   line: int, origin: Optional[FuncSummary],
                   findings: List[Finding]) -> None:
    path, qual = str(fn.path), fn.qualname
    via = ""
    also: Tuple[int, ...] = ()
    if origin is not None and origin is not fn:
        via = f" (via {origin.qualname})"
        if origin.path == fn.path:
            also = tuple(a.line for a in origin.acquires
                         if a.tok.ident == tok.ident)[:1]

    if tok.rank is not None and lockspec.LEVEL_BY_NAME[tok.level].multi \
            == "sorted" and kind == "bare" and in_loop and not loop_sorted:
        findings.append(Finding(
            rule="WTF001", path=path, line=line, qualname=qual,
            message=f"'{tok.level}' locks acquired in a loop over an "
                    f"unsorted iterable{via}",
            detail="the declared order requires strictly ascending "
                   "(shard, stripe) keys; iterate sorted(...)",
            also_lines=also))

    if tok.rank is None:
        return
    for h in held:
        if h.rank is None:
            continue
        if h.rank > tok.rank:
            findings.append(Finding(
                rule="WTF001", path=path, line=line, qualname=qual,
                message=f"acquires '{tok.level}' (rank {tok.rank}) while "
                        f"holding '{h.level}' (rank {h.rank}){via}",
                detail=f"declared order: {h.level} is inner to {tok.level}; "
                       f"outer lock held since line {h.line}",
                also_lines=also))
        elif h.rank == tok.rank:
            level = lockspec.LEVEL_BY_NAME[tok.level]
            if level.multi == "sorted":
                if not (in_loop and loop_sorted):
                    findings.append(Finding(
                        rule="WTF001", path=path, line=line, qualname=qual,
                        message=f"multiple '{tok.level}' locks held without "
                                f"sorted acquisition{via}",
                        detail="same-level families may only be "
                               "multi-acquired in ascending key order",
                        also_lines=also))
            elif h.ident != tok.ident or tok.keyed:
                findings.append(Finding(
                    rule="WTF001", path=path, line=line, qualname=qual,
                    message=f"holds two locks of level '{tok.level}' "
                            f"(multi=none){via}",
                    also_lines=also))


def rule_wtf001(mods: Sequence[ModuleSummary], index: Index,
                memo: Dict[str, Effects],
                findings: List[Finding]) -> None:
    edges: Dict[Tuple[str, str], Tuple[str, int, str]] = {}
    for m in mods:
        for fn in m.functions:
            for a in fn.acquires:
                _check_acquire(a.tok, a.held, a.kind, a.in_loop,
                               a.loop_sorted, fn, a.line, None, findings)
                for h in a.held:
                    edges.setdefault(
                        (h.ident, a.tok.ident),
                        (str(fn.path), a.line, fn.qualname))
            for c in fn.calls:
                if not c.held:
                    continue
                callee = index.resolve(c.chain, fn)
                if callee is None or callee is fn:
                    continue
                eff = _effects(callee, index, memo, set())
                for a, origin in eff.acquires:
                    if any(h.ident == a.tok.ident and not a.tok.keyed
                           for h in c.held):
                        continue  # reentrant re-acquire of the same lock
                    _check_acquire(a.tok, c.held, a.kind,
                                   a.in_loop, a.loop_sorted or a.kind ==
                                   "with", fn, c.line, origin, findings)
                    for h in c.held:
                        edges.setdefault(
                            (h.ident, a.tok.ident),
                            (str(fn.path), c.line, fn.qualname))

    # cycle detection over the full graph (catches unranked locks too)
    graph: Dict[str, Set[str]] = {}
    for (a, b) in edges:
        if a == b:
            continue
        graph.setdefault(a, set()).add(b)
    seen_cycles: Set[Tuple[str, ...]] = set()

    def dfs(node: str, trail: List[str], visiting: Set[str]) -> None:
        for nxt in graph.get(node, ()):
            if nxt in visiting:
                i = trail.index(nxt)
                cycle = tuple(sorted(trail[i:]))
                if cycle not in seen_cycles:
                    seen_cycles.add(cycle)
                    path, line, qual = edges[(node, nxt)]
                    findings.append(Finding(
                        rule="WTF001", path=path, line=line, qualname=qual,
                        message="lock-acquisition cycle: "
                                + " -> ".join(trail[i:] + [nxt])))
            else:
                visiting.add(nxt)
                dfs(nxt, trail + [nxt], visiting)
                visiting.discard(nxt)

    for start in list(graph):
        dfs(start, [start], {start})


# --------------------------------------------------------------- WTF002

def rule_wtf002(mods: Sequence[ModuleSummary], index: Index,
                memo: Dict[str, Effects],
                findings: List[Finding]) -> None:
    emitted: Set[Tuple[str, int, str]] = set()

    def emit(path: str, line: int, qual: str, message: str,
             also: Tuple[int, ...]) -> None:
        dkey = (path, line, message)
        if dkey in emitted:
            return
        emitted.add(dkey)
        findings.append(Finding(rule="WTF002", path=path, line=line,
                                qualname=qual, message=message,
                                also_lines=also))

    for m in mods:
        for fn in m.functions:
            for c in fn.calls:
                if not c.held:
                    continue
                inner = c.held[-1]
                lockname = inner.level or inner.attr
                if _is_blocking(c.chain, fn, index):
                    emit(str(fn.path), c.line, fn.qualname,
                         f"blocking call '{c.chain}' under lock "
                         f"'{lockname}'", (inner.line,))
                    continue
                callee = index.resolve(c.chain, fn)
                if callee is None or callee is fn:
                    continue
                eff = _effects(callee, index, memo, set())
                for b, origin in eff.blocking:
                    also = (c.line, inner.line) if origin.path == fn.path \
                        else ()
                    emit(str(origin.path), b.line, origin.qualname,
                         f"blocking call '{b.chain}' reached under lock "
                         f"'{lockname}' held at {fn.qualname}:{c.line}",
                         also)


# --------------------------------------------------------------- WTF003

def rule_wtf003(mods: Sequence[ModuleSummary], index: Index,
                findings: List[Finding]) -> None:
    for m in mods:
        for c in m.classes.values():
            if not c.lock_attrs:
                continue
            methods = [f for f in m.functions if f.cls == c.name
                       and f.name not in ("__init__", "__post_init__")]
            assign_sites: Dict[str, List[Tuple[bool, int, str]]] = {}
            for fn in methods:
                for w in fn.writes:
                    parts = w.chain.split(".")
                    if parts[0] != "self" or len(parts) != 2:
                        continue
                    attr = parts[1]
                    if attr in c.lock_attrs:
                        continue
                    if w.is_aug:
                        if not w.held:
                            findings.append(Finding(
                                rule="WTF003", path=str(fn.path),
                                line=w.line, qualname=fn.qualname,
                                message=f"augmented write to shared "
                                        f"'self.{attr}' outside any lock",
                                detail="read-modify-write on an attribute "
                                       "of a lock-owning class; lost "
                                       "updates under concurrency"))
                    else:
                        assign_sites.setdefault(attr, []).append(
                            (bool(w.held), w.line, fn.qualname))
            for attr, sites in assign_sites.items():
                if any(h for h, _, _ in sites) and \
                        any(not h for h, _, _ in sites):
                    for h, line, qual in sites:
                        if not h:
                            findings.append(Finding(
                                rule="WTF003", path=str(c.path), line=line,
                                qualname=qual,
                                message=f"mixed locking discipline: "
                                        f"'self.{attr}' assigned outside a "
                                        f"lock here but under a lock "
                                        f"elsewhere"))

        # stats-bypass: '+=' on a field of an attribute this class assigned
        # from an AtomicStatsMixin dataclass (locked class or not)
        for fn in m.functions:
            if fn.cls is None:
                continue
            info = m.classes.get(fn.cls)
            if info is None or not info.stats_attrs:
                continue
            for w in fn.writes:
                parts = w.chain.split(".")
                if w.is_aug and len(parts) == 3 and parts[0] == "self" \
                        and parts[1] in info.stats_attrs:
                    findings.append(Finding(
                        rule="WTF003", path=str(fn.path), line=w.line,
                        qualname=fn.qualname,
                        message=f"'{w.chain} +=' bypasses "
                                f"AtomicStatsMixin.add()",
                        detail="stats dataclasses are mutated from pool "
                               "threads; use .add(field=delta)"))


# --------------------------------------------------------------- WTF004

def _stmts_in_order(node: ast.AST):
    for st in getattr(node, "body", []):
        yield st
        for fld in ("body", "orelse", "finalbody"):
            for sub in getattr(st, fld, []) or []:
                yield from _yield_tree(sub)
        for handler in getattr(st, "handlers", []) or []:
            for sub in handler.body:
                yield from _yield_tree(sub)


def _yield_tree(st: ast.stmt):
    yield st
    for fld in ("body", "orelse", "finalbody"):
        for sub in getattr(st, fld, []) or []:
            yield from _yield_tree(sub)
    for handler in getattr(st, "handlers", []) or []:
        for sub in handler.body:
            yield from _yield_tree(sub)


def _chain(node: ast.AST) -> Optional[str]:
    from .scanner import chain_of
    return chain_of(node)


def rule_wtf004(mods: Sequence[ModuleSummary], index: Index,
                findings: List[Finding]) -> None:
    for m in mods:
        for c in m.classes.values():
            if "CommutingOp" not in c.bases and c.name != "CommutingOp":
                continue
            fn = index.exact.get((m.module, c.name, "apply"))
            if fn is None:
                continue
            _check_apply(c, fn, findings)
            if c.flags.get("version_preserving"):
                _check_version_preserving(c, fn, findings)


def _check_apply(c: ClassInfo, fn: FuncSummary,
                 findings: List[Finding]) -> None:
    path, qual = str(fn.path), fn.qualname

    def emit(line: int, message: str, detail: str = "") -> None:
        findings.append(Finding(rule="WTF004", path=path, line=line,
                                qualname=qual, message=message,
                                detail=detail))

    state: Dict[str, str] = {p: "alias" for p in fn.params if p != "self"}

    def rooted_alias(node: ast.AST) -> Optional[str]:
        chain = _chain(node)
        if chain is None:
            return None
        root = chain.split(".")[0]
        if root == "self":
            return "self"
        if state.get(root) == "alias":
            return root
        return None

    for st in _stmts_in_order(fn.node):
        if isinstance(st, ast.Raise):
            exc = None
            if st.exc is not None:
                node = st.exc.func if isinstance(st.exc, ast.Call) else st.exc
                exc = _chain(node)
            if c.name == "CommutingOp" or exc == "NotImplementedError":
                continue
            emit(st.lineno, "raise inside CommutingOp.apply",
                 "apply cannot fail (paper §2.5): validate in "
                 "precondition(), not at apply time")
            continue

        if isinstance(st, ast.Assign):
            for tgt in st.targets:
                if isinstance(tgt, ast.Name):
                    value = st.value
                    if isinstance(value, ast.Call):
                        state[tgt.id] = "fresh"
                    elif rooted_alias(value) is not None:
                        state[tgt.id] = "alias"
                    else:
                        state[tgt.id] = "fresh"
                elif isinstance(tgt, (ast.Subscript, ast.Attribute)):
                    root = rooted_alias(tgt)
                    if root == "self":
                        emit(st.lineno,
                             "apply mutates op state (self.*)",
                             "ops must be immutable; build and return "
                             "fresh values")
                    elif root is not None:
                        emit(st.lineno,
                             f"apply mutates its input '{root}' in place",
                             "copy first (e.g. list(value)) and mutate "
                             "the copy")

        if isinstance(st, ast.AugAssign):
            root = rooted_alias(st.target)
            if root == "self":
                emit(st.lineno, "apply mutates op state (self.*)")
            elif root is not None:
                emit(st.lineno,
                     f"apply mutates its input '{root}' in place")

        for node in ast.walk(st):
            if not isinstance(node, ast.Call):
                continue
            chain = _chain(node.func)
            if chain is None:
                continue
            parts = chain.split(".")
            if chain in ("open", "print", "input") or \
                    parts[0] in IMPURE_ROOTS:
                emit(node.lineno,
                     f"impure call '{chain}' in apply",
                     "apply must be deterministic and side-effect free")
                continue
            if set(parts) & KV_COMPONENTS:
                emit(node.lineno,
                     f"apply reads KV/transaction state via '{chain}'",
                     "commuting ops receive their operand; reading live "
                     "state breaks commutativity")
                continue
            if len(parts) >= 2 and parts[-1] in MUTATOR_METHODS:
                root = parts[0]
                if root == "self" and len(parts) > 2:
                    emit(node.lineno,
                         f"apply mutates op state via '{chain}'")
                elif state.get(root) == "alias":
                    emit(node.lineno,
                         f"apply mutates its input via '{chain}'",
                         "copy first (e.g. list(value)) and mutate "
                         "the copy")


def _check_version_preserving(c: ClassInfo, fn: FuncSummary,
                              findings: List[Finding]) -> None:
    for st in _stmts_in_order(fn.node):
        if not isinstance(st, ast.Return) or \
                not isinstance(st.value, ast.Call):
            continue
        call = st.value
        ctor = (_chain(call.func) or "").split(".")[-1]
        if ctor != "RegionData":
            continue
        end_arg: Optional[ast.AST] = None
        for kw in call.keywords:
            if kw.arg == "end":
                end_arg = kw.value
        if end_arg is None and len(call.args) >= 2:
            end_arg = call.args[1]
        if end_arg is None:
            continue
        if not (isinstance(end_arg, ast.Attribute)
                and end_arg.attr == "end"):
            findings.append(Finding(
                rule="WTF004", path=str(fn.path), line=st.lineno,
                qualname=fn.qualname,
                message="version_preserving op does not carry 'end' "
                        "through verbatim",
                detail="validators compare region end; rebuilding it "
                       "breaks preserves-version commits"))


# ----------------------------------------------------------------- driver

def run_rules(mods: Sequence[ModuleSummary],
              only: Optional[Set[str]] = None) -> List[Finding]:
    index = Index.build(mods)
    memo: Dict[str, Effects] = {}
    findings: List[Finding] = []
    if only is None or "WTF001" in only:
        rule_wtf001(mods, index, memo, findings)
    if only is None or "WTF002" in only:
        rule_wtf002(mods, index, memo, findings)
    if only is None or "WTF003" in only:
        rule_wtf003(mods, index, findings)
    if only is None or "WTF004" in only:
        rule_wtf004(mods, index, findings)
    return findings
