"""AST scanner: turn each function into a linear stream of events
(acquisitions, calls, attribute writes, raises), each annotated with the
set of locks held at that point.

The model is deliberately simple and over-approximate in the direction
that suits a linter:

* ``with self._lock:`` holds for the lexical body and releases at exit;
* a bare ``lock.acquire()`` statement holds from that point to the end of
  the enclosing block (the ``acquire``-loop / ``try/finally``-release
  idiom used by group commit), and a bare ``.release()`` drops the most
  recent matching acquisition;
* branches (``if``/``try``) are walked with the same held set and their
  net acquisitions leak to the following statements (union of paths).

Names assigned from ``sorted(...)`` are tracked so rules can tell a
sorted stripe-acquisition loop from an unsorted one.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

from . import lockspec

#: with-targets / acquire-targets are treated as locks when they resolve to
#: a declared level, are a known lock attribute of the class, or just look
#: like a lock by name.
LOCKISH_NAME = re.compile(r"(lock|mutex|_cond\b|_idle\b|_stripes)", re.I)

_LOCK_CTORS = {
    "Lock": "lock",
    "RLock": "rlock",
    "Condition": "condition",
    "witness_lock": "lock",
}


@dataclass(frozen=True)
class LockTok:
    ident: str                   # graph identity: level name or module:cls:attr
    attr: str
    level: Optional[str]
    rank: Optional[int]
    line: int
    keyed: bool = False          # acquired through a subscript (lock family)


@dataclass(frozen=True)
class AcquireEvent:
    tok: LockTok
    held: Tuple[LockTok, ...]
    line: int
    kind: str                    # "with" | "bare"
    in_loop: bool = False
    loop_sorted: bool = False


@dataclass(frozen=True)
class CallEvent:
    chain: str                   # dotted callee chain, e.g. "os.pwrite"
    held: Tuple[LockTok, ...]
    line: int


@dataclass(frozen=True)
class WriteEvent:
    chain: str                   # dotted target, e.g. "self._rr"
    is_aug: bool
    held: Tuple[LockTok, ...]
    line: int


@dataclass(frozen=True)
class RaiseEvent:
    line: int
    exc: Optional[str]


@dataclass
class FuncSummary:
    module: str
    path: Path
    cls: Optional[str]
    name: str
    qualname: str
    node: ast.AST
    params: Tuple[str, ...]
    acquires: List[AcquireEvent] = field(default_factory=list)
    calls: List[CallEvent] = field(default_factory=list)
    writes: List[WriteEvent] = field(default_factory=list)
    raises: List[RaiseEvent] = field(default_factory=list)


@dataclass
class ClassInfo:
    module: str
    path: Path
    name: str
    bases: Tuple[str, ...]
    node: ast.ClassDef
    lock_attrs: Dict[str, str] = field(default_factory=dict)
    stats_attrs: Set[str] = field(default_factory=set)
    flags: Dict[str, object] = field(default_factory=dict)


@dataclass
class ModuleSummary:
    path: Path
    module: str
    source: str
    tree: ast.Module
    functions: List[FuncSummary] = field(default_factory=list)
    classes: Dict[str, ClassInfo] = field(default_factory=dict)


# --------------------------------------------------------------- helpers

def chain_of(node: ast.AST) -> Optional[str]:
    """Dotted rendering of an attribute/name chain; ``[]``/``()`` mark
    subscripts and intermediate calls.  Returns None for non-chains."""
    parts: List[str] = []
    while True:
        if isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        elif isinstance(node, ast.Subscript):
            parts.append("[]")
            node = node.value
        elif isinstance(node, ast.Call):
            parts.append("()")
            node = node.func
        elif isinstance(node, ast.Name):
            parts.append(node.id)
            break
        else:
            return None
    return ".".join(reversed(parts))


def _const_true(node: ast.AST) -> bool:
    return isinstance(node, ast.Constant) and node.value is True


class _LoopCtx:
    __slots__ = ("in_loop", "is_sorted")

    def __init__(self, in_loop: bool = False, is_sorted: bool = False):
        self.in_loop = in_loop
        self.is_sorted = is_sorted


_COMPOUND_BODY_FIELDS = {"body", "orelse", "finalbody", "handlers"}


class _FuncWalker:
    """Single-function walker producing the event stream."""

    def __init__(self, module: ModuleSummary, cls: Optional[ClassInfo],
                 qualname: str, node: ast.AST):
        args = node.args
        params = tuple(a.arg for a in
                       list(args.posonlyargs) + list(args.args)
                       + list(args.kwonlyargs))
        self.mod = module
        self.cls = cls
        self.out = FuncSummary(
            module=module.module, path=module.path,
            cls=cls.name if cls else None, name=node.name,
            qualname=qualname, node=node, params=params)
        self.sorted_names: Set[str] = set()
        self.nested: List[ast.AST] = []

    # -- lock classification ---------------------------------------------
    def _tok(self, expr: ast.AST) -> Optional[LockTok]:
        keyed = False
        node = expr
        if isinstance(node, ast.Subscript):
            keyed = True
            node = node.value
        attr: Optional[str] = None
        owner_cls: Optional[str] = None
        if isinstance(node, ast.Attribute):
            attr = node.attr
            base = chain_of(node.value)
            if base == "self" and self.cls is not None:
                owner_cls = self.cls.name
        elif isinstance(node, ast.Name):
            attr = node.id
        if attr is None:
            return None
        level = lockspec.level_for(self.mod.module, owner_cls, attr)
        known_lock = (owner_cls is not None and self.cls is not None
                      and attr in self.cls.lock_attrs)
        if level is None and not known_lock and not LOCKISH_NAME.search(attr):
            return None
        ident = level or f"{self.mod.module}:{owner_cls or ''}:{attr}"
        return LockTok(ident=ident, attr=attr, level=level,
                       rank=lockspec.rank_of(level),
                       line=getattr(expr, "lineno", 0), keyed=keyed)

    # -- event emission ---------------------------------------------------
    def _emit_header_calls(self, st: ast.stmt, held: List[LockTok]) -> None:
        snapshot = tuple(held)
        stack: List[ast.AST] = []
        for fname, value in ast.iter_fields(st):
            if fname in _COMPOUND_BODY_FIELDS:
                continue
            if isinstance(value, ast.AST):
                stack.append(value)
            elif isinstance(value, list):
                stack.extend(v for v in value if isinstance(v, ast.AST))
        while stack:
            node = stack.pop()
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda, ast.ClassDef)):
                continue
            if isinstance(node, ast.Call):
                chain = chain_of(node.func)
                if chain is not None:
                    self.out.calls.append(CallEvent(
                        chain=chain, held=snapshot, line=node.lineno))
            stack.extend(ast.iter_child_nodes(node))

    def _note_sorted(self, st: ast.Assign) -> None:
        value = st.value
        if isinstance(value, ast.Call):
            fn = chain_of(value.func)
            if fn == "sorted":
                for tgt in st.targets:
                    if isinstance(tgt, ast.Name):
                        self.sorted_names.add(tgt.id)

    def _iter_is_sorted(self, it: ast.AST) -> bool:
        if isinstance(it, ast.Name):
            return it.id in self.sorted_names
        if isinstance(it, ast.Call):
            return chain_of(it.func) == "sorted"
        return False

    # -- statement walk ---------------------------------------------------
    def walk(self) -> FuncSummary:
        self._walk_block(self.out.node.body, [], _LoopCtx())
        return self.out

    def _walk_block(self, stmts: Sequence[ast.stmt], held: List[LockTok],
                    loop: _LoopCtx) -> None:
        for st in stmts:
            self._walk_stmt(st, held, loop)

    def _walk_stmt(self, st: ast.stmt, held: List[LockTok],
                   loop: _LoopCtx) -> None:
        if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
            self.nested.append(st)
            return
        if isinstance(st, ast.ClassDef):
            return
        self._emit_header_calls(st, held)

        if isinstance(st, (ast.With, ast.AsyncWith)):
            toks: List[LockTok] = []
            for item in st.items:
                tok = self._tok(item.context_expr)
                if tok is not None:
                    self.out.acquires.append(AcquireEvent(
                        tok=tok, held=tuple(held), line=tok.line,
                        kind="with", in_loop=loop.in_loop,
                        loop_sorted=loop.is_sorted))
                    toks.append(tok)
            held.extend(toks)
            self._walk_block(st.body, held, loop)
            for tok in toks:
                self._drop(held, tok)
            return

        if isinstance(st, (ast.For, ast.AsyncFor)):
            inner = _LoopCtx(True, self._iter_is_sorted(st.iter))
            self._walk_block(st.body, held, inner)
            self._walk_block(st.orelse, held, loop)
            return

        if isinstance(st, ast.While):
            self._walk_block(st.body, held, _LoopCtx(True, False))
            self._walk_block(st.orelse, held, loop)
            return

        if isinstance(st, ast.If):
            self._walk_block(st.body, held, loop)
            self._walk_block(st.orelse, held, loop)
            return

        if isinstance(st, ast.Try):
            self._walk_block(st.body, held, loop)
            for handler in st.handlers:
                self._walk_block(handler.body, held, loop)
            self._walk_block(st.orelse, held, loop)
            self._walk_block(st.finalbody, held, loop)
            return

        if isinstance(st, ast.Expr) and isinstance(st.value, ast.Call):
            chain = chain_of(st.value.func) or ""
            if chain.endswith(".acquire"):
                tok = self._tok(st.value.func.value)
                if tok is not None:
                    self.out.acquires.append(AcquireEvent(
                        tok=tok, held=tuple(held), line=st.value.lineno,
                        kind="bare", in_loop=loop.in_loop,
                        loop_sorted=loop.is_sorted))
                    held.append(tok)
                return
            if chain.endswith(".release"):
                tok = self._tok(st.value.func.value)
                if tok is not None:
                    self._drop(held, tok)
                return
            return

        if isinstance(st, ast.Assign):
            self._note_sorted(st)
            for tgt in st.targets:
                chain = chain_of(tgt)
                if chain is not None:
                    self.out.writes.append(WriteEvent(
                        chain=chain, is_aug=False, held=tuple(held),
                        line=st.lineno))
            return

        if isinstance(st, ast.AugAssign):
            chain = chain_of(st.target)
            if chain is not None:
                self.out.writes.append(WriteEvent(
                    chain=chain, is_aug=True, held=tuple(held),
                    line=st.lineno))
            return

        if isinstance(st, ast.Raise):
            exc = None
            if st.exc is not None:
                node = st.exc
                if isinstance(node, ast.Call):
                    node = node.func
                exc = chain_of(node)
            self.out.raises.append(RaiseEvent(line=st.lineno, exc=exc))
            return

    @staticmethod
    def _drop(held: List[LockTok], tok: LockTok) -> None:
        for i in range(len(held) - 1, -1, -1):
            if held[i].attr == tok.attr and held[i].ident == tok.ident:
                del held[i]
                return


# ----------------------------------------------------------- class intro

def _base_names(node: ast.ClassDef) -> Tuple[str, ...]:
    out = []
    for b in node.bases:
        chain = chain_of(b)
        if chain:
            out.append(chain.split(".")[-1])
    return tuple(out)


def _lock_kind_of_value(value: ast.AST) -> Optional[str]:
    """Classify ``threading.Lock()`` / ``witness_lock(...)`` ctor calls."""
    if not isinstance(value, ast.Call):
        return None
    chain = chain_of(value.func)
    if chain is None:
        return None
    name = chain.split(".")[-1]
    kind = _LOCK_CTORS.get(name)
    if kind is None:
        return None
    if name == "witness_lock" and value.args:
        inner = _lock_kind_of_value(value.args[0])
        return inner or "lock"
    return kind


def _fill_class_info(info: ClassInfo, stats_classes: Set[str]) -> None:
    for st in info.node.body:
        # dataclass-style:  _stats_lock: Lock = field(default_factory=Lock)
        if isinstance(st, ast.AnnAssign) and isinstance(st.target, ast.Name):
            value = st.value
            if isinstance(value, ast.Call) and \
                    (chain_of(value.func) or "").endswith("field"):
                for kw in value.keywords:
                    if kw.arg == "default_factory":
                        chain = chain_of(kw.value) or ""
                        kind = _LOCK_CTORS.get(chain.split(".")[-1])
                        if kind:
                            info.lock_attrs[st.target.id] = kind
        if isinstance(st, ast.Assign):
            for tgt in st.targets:
                if isinstance(tgt, ast.Name) and _const_true(st.value):
                    info.flags[tgt.id] = True
        if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for sub in ast.walk(st):
                if not isinstance(sub, ast.Assign):
                    continue
                for tgt in sub.targets:
                    chain = chain_of(tgt)
                    if chain is None or not chain.startswith("self.") \
                            or chain.count(".") != 1:
                        continue
                    attr = chain.split(".")[1]
                    kind = _lock_kind_of_value(sub.value)
                    if kind is not None:
                        info.lock_attrs.setdefault(attr, kind)
                        continue
                    if isinstance(sub.value, ast.Call):
                        ctor = (chain_of(sub.value.func) or "").split(".")[-1]
                        if ctor in stats_classes:
                            info.stats_attrs.add(attr)


# -------------------------------------------------------------- scanning

def _scan_function(mod: ModuleSummary, cls: Optional[ClassInfo],
                   qualname: str, node: ast.AST,
                   out: List[FuncSummary]) -> None:
    walker = _FuncWalker(mod, cls, qualname, node)
    out.append(walker.walk())
    for nested in walker.nested:
        _scan_function(mod, cls, f"{qualname}.<locals>.{nested.name}",
                       nested, out)


def scan_paths(paths: Sequence[Path]) -> List[ModuleSummary]:
    """Parse every ``*.py`` under the given files/directories and build
    module summaries (two passes so stats classes resolve globally)."""
    files: List[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            files.extend(sorted(f for f in p.rglob("*.py")
                                if "__pycache__" not in f.parts))
        elif p.suffix == ".py":
            files.append(p)

    mods: List[ModuleSummary] = []
    for f in files:
        source = f.read_text()
        try:
            tree = ast.parse(source, filename=str(f))
        except SyntaxError:
            continue
        mod = ModuleSummary(path=f, module=f.stem, source=source, tree=tree)
        for st in tree.body:
            if isinstance(st, ast.ClassDef):
                mod.classes[st.name] = ClassInfo(
                    module=mod.module, path=f, name=st.name,
                    bases=_base_names(st), node=st)
        mods.append(mod)

    stats_classes = {c.name for m in mods for c in m.classes.values()
                     if "AtomicStatsMixin" in c.bases}

    for mod in mods:
        for cls in mod.classes.values():
            _fill_class_info(cls, stats_classes)
        for st in mod.tree.body:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
                _scan_function(mod, None, st.name, st, mod.functions)
            elif isinstance(st, ast.ClassDef):
                cls = mod.classes[st.name]
                for sub in st.body:
                    if isinstance(sub, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
                        _scan_function(mod, cls, f"{st.name}.{sub.name}",
                                       sub, mod.functions)
    return mods


def stats_class_names(mods: Sequence[ModuleSummary]) -> Set[str]:
    return {c.name for m in mods for c in m.classes.values()
            if "AtomicStatsMixin" in c.bases}
