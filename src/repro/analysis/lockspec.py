"""Machine-readable declaration of the repo's global lock order.

This is the single source of truth consumed by BOTH checkers:

* the static pass (``analysis.rules`` / WTF001) maps ``with self._lock:``
  sites to levels via :data:`STATIC_LOCK_MAP` and flags acquisition edges
  that run *down* the declared order (or cycles among unranked locks), and
* the runtime witness (``core.testing.witness_lock`` /
  ``LockOrderWatchdog``) wraps the real lock objects with the same level
  names and asserts, at acquisition time, that every thread's held-lock
  stack is consistent with :data:`LOCK_LEVELS`.

Ranks ascend from outermost to innermost: a thread may only acquire a lock
whose rank is **strictly greater** than every ranked lock it already holds,
except for same-level families declared ``multi="sorted"`` (the stripe
locks), where additional locks of the same level may be taken as long as
their keys are strictly ascending — this encodes the global
``(shard, stripe)`` acquisition order that group commit and cross-shard 2PC
rely on (commit-queue < stripe < WAL, stripes sorted).

Locks that are not in the map (per-test helpers, ``_stats_lock`` leaves,
client-side caches) are simply unranked: the witness does not wrap them and
the static pass only includes them in cycle detection, not rank checks.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class LockLevel:
    """One level in the global order.

    ``multi`` declares what holding *several* locks of this level means:

    * ``"none"``  — never legal to hold two distinct locks of this level;
    * ``"sorted"`` — legal iff acquired in strictly ascending ``key`` order
      (keys are supplied at ``witness_lock`` wrap time, e.g.
      ``(shard_index, stripe_id)``).
    """

    name: str
    rank: int
    multi: str = "none"            # "none" | "sorted"
    doc: str = ""


#: Declared global order, outermost (lowest rank) first.  Derived from the
#: documented protocols: group commit takes the commit queue, then the
#: sorted stripe set, then (per write) the WAL; the lease invalidation
#: barrier runs under the stripes; WAL listeners (shard fan-in, the log
#: consumer watermark, plan-cache invalidation) run under the WAL lock;
#: storage locks never nest inside metadata-plane commits the other way.
LOCK_LEVELS: Tuple[LockLevel, ...] = (
    LockLevel("repair.queue", 5,
              doc="RepairQueue ticket map (outermost: the repair daemon "
                  "may hold it only before touching any metadata/storage "
                  "lock; drain() copies tickets out and releases before "
                  "processing)"),
    LockLevel("kv.commit_queue", 10,
              doc="WarpKV group-commit queue mutex (taken alone, briefly)"),
    LockLevel("kv.stripe", 20, multi="sorted",
              doc="per-stripe RLocks; key=(shard, stripe), ascending"),
    LockLevel("lease.tables", 30,
              doc="LeaseHub registry of per-client tables"),
    LockLevel("lease.table", 40,
              doc="one client's LeaseTable (barrier revokes sequentially)"),
    LockLevel("kv.wal", 50,
              doc="per-shard WAL + listener fan-out (RLock; reentrant "
                  "commit from a listener is the documented exception)"),
    LockLevel("sub.fanin", 60,
              doc="ShardedKV.subscribe per-subscriber serialization lock"),
    LockLevel("wlog.consumer", 70,
              doc="LogConsumer commit-watermark condition"),
    LockLevel("cache.plan", 80,
              doc="PlanCache map (invalidated from WAL listeners)"),
    LockLevel("cache.block", 85,
              doc="BlockCache LRU map (invalidated from the same WAL "
                  "listeners / plan-validation failures as cache.plan; "
                  "taken after it on joint evictions)"),
    LockLevel("kv.space", 90,
              doc="WarpKV space-dict creation (leaf, under stripes)"),
    LockLevel("storage.files", 100,
              doc="StorageServer backing-file directory"),
    LockLevel("storage.backing", 110,
              doc="per-backing-file offset reservation / quiesce lock"),
    LockLevel("storage.readahead", 115,
              doc="per-server readahead buffer pool (leaf under "
                  "storage.backing: sparse rewrite invalidates the pool "
                  "while holding the backing-file lock, so the pool lock "
                  "must never wrap a backing-file read)"),
    LockLevel("kv.service", 120,
              doc="modeled metadata service-time serialization (leaf; "
                  "sleeps by design)"),
    LockLevel("iort.health", 125,
              doc="HealthTracker circuit/EWMA state (innermost leaf: "
                  "consulted from failover walks deep inside data-plane "
                  "rounds; nothing blocks or nests under it)"),
)

LEVEL_BY_NAME: Dict[str, LockLevel] = {lv.name: lv for lv in LOCK_LEVELS}
RANK: Dict[str, int] = {lv.name: lv.rank for lv in LOCK_LEVELS}


#: Exact (module basename, class name, attribute) -> level name.  ``None``
#: class matches any enclosing class (used for closure-local lock names).
STATIC_LOCK_MAP: Dict[Tuple[str, Optional[str], str], str] = {
    ("metadata", "WarpKV", "_commit_queue_lock"): "kv.commit_queue",
    ("metadata", "WarpKV", "_stripes"): "kv.stripe",
    ("metadata", "WarpKV", "_wal_lock"): "kv.wal",
    ("metadata", "WarpKV", "_space_lock"): "kv.space",
    ("metadata", "WarpKV", "_service_lock"): "kv.service",
    ("lease", "LeaseHub", "_tables_lock"): "lease.tables",
    ("lease", "LeaseTable", "_lock"): "lease.table",
    ("mdshard", None, "sub_lock"): "sub.fanin",
    ("wlog", "LogConsumer", "_cond"): "wlog.consumer",
    ("iort", "PlanCache", "_lock"): "cache.plan",
    ("iort", "HealthTracker", "_lock"): "iort.health",
    ("repair", "RepairQueue", "_lock"): "repair.queue",
    ("blockcache", "BlockCache", "_lock"): "cache.block",
    ("storage", "_ReadaheadPool", "_lock"): "storage.readahead",
    ("storage", "StorageServer", "_files_lock"): "storage.files",
    ("storage", "_BackingFile", "lock"): "storage.backing",
    ("storage", "_BackingFile", "_idle"): "storage.backing",
    # cross-object uses like ``with bf.lock:`` inside StorageServer
    ("storage", None, "lock"): "storage.backing",
}

#: Fallback mapping by attribute name alone, for code (and test fixtures)
#: that uses the canonical attribute names outside the exact modules above.
ATTR_LOCK_MAP: Dict[str, str] = {
    "_commit_queue_lock": "kv.commit_queue",
    "_stripes": "kv.stripe",
    "_wal_lock": "kv.wal",
    "_space_lock": "kv.space",
    "_service_lock": "kv.service",
    "_tables_lock": "lease.tables",
    "_files_lock": "storage.files",
    "sub_lock": "sub.fanin",
}


def level_for(module: str, cls: Optional[str], attr: str) -> Optional[str]:
    """Resolve a lock attribute to its declared level name, or ``None``."""
    hit = STATIC_LOCK_MAP.get((module, cls, attr))
    if hit is not None:
        return hit
    hit = STATIC_LOCK_MAP.get((module, None, attr))
    if hit is not None:
        return hit
    return ATTR_LOCK_MAP.get(attr)


def rank_of(level: Optional[str]) -> Optional[int]:
    if level is None:
        return None
    return RANK.get(level)


def declared_order_doc() -> str:
    """Human-readable one-liner-per-level rendering of the order."""
    lines = ["Declared lock order (outermost first):"]
    for lv in LOCK_LEVELS:
        multi = " [multi: sorted keys]" if lv.multi == "sorted" else ""
        lines.append(f"  {lv.rank:>4}  {lv.name:<16}{multi}  {lv.doc}")
    return "\n".join(lines)
