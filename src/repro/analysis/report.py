"""Findings, inline suppressions, baseline, and report rendering."""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

RULES: Dict[str, str] = {
    "WTF001": "lock-order violation / unordered multi-acquisition",
    "WTF002": "blocking call under a lock",
    "WTF003": "unprotected write to shared state / stats bypass",
    "WTF004": "impure or version-unsafe CommutingOp",
}

_SUPPRESS_RE = re.compile(
    r"#\s*wtf-lint:\s*ignore\[([A-Za-z0-9*,\s]+)\]\s*(?:--\s*([^#]*))?")


@dataclass
class Finding:
    rule: str
    path: str                    # repo-relative if possible
    line: int
    qualname: str
    message: str
    detail: str = ""
    #: extra source lines where a suppression comment also silences this
    #: finding (origin of an interprocedural effect, governing ``with``).
    also_lines: Tuple[int, ...] = ()
    suppressed: bool = False
    suppress_reason: str = ""
    baselined: bool = False

    @property
    def key(self) -> str:
        """Line-number-insensitive identity used by the baseline file."""
        return f"{self.rule}:{self.path}:{self.qualname}:{self.message}"

    def to_json(self) -> dict:
        return {
            "rule": self.rule,
            "title": RULES.get(self.rule, ""),
            "path": self.path,
            "line": self.line,
            "function": self.qualname,
            "message": self.message,
            "detail": self.detail,
            "suppressed": self.suppressed,
            "suppress_reason": self.suppress_reason,
            "baselined": self.baselined,
        }


@dataclass
class Suppressions:
    """Parsed ``# wtf-lint: ignore[...] -- reason`` comments of one file."""
    #: line -> (rule ids, reason, standalone-comment-line?)
    by_line: Dict[int, Tuple[Set[str], str, bool]] = field(
        default_factory=dict)
    bare: List[int] = field(default_factory=list)   # ignores missing a reason

    @classmethod
    def parse(cls, source: str) -> "Suppressions":
        out = cls()
        for lineno, text in enumerate(source.splitlines(), start=1):
            m = _SUPPRESS_RE.search(text)
            if not m:
                continue
            rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
            reason = (m.group(2) or "").strip()
            if not reason:
                out.bare.append(lineno)
                continue
            standalone = text.lstrip().startswith("#")
            out.by_line[lineno] = (rules, reason, standalone)
        return out

    def match(self, rule: str, lines: Iterable[int]) -> Optional[str]:
        # an inline suppression covers its own line; a standalone comment
        # line covers the statement directly below it
        for ln in lines:
            for anchor, need_standalone in ((ln, False), (ln - 1, True)):
                hit = self.by_line.get(anchor)
                if hit and (rule in hit[0] or "*" in hit[0]) \
                        and (hit[2] or not need_standalone):
                    return hit[1]
        return None


def apply_suppressions(findings: List[Finding],
                       sources: Dict[str, str]) -> List[Finding]:
    """Mark findings silenced by inline comments; emit a finding for any
    ignore comment that lacks a justification."""
    parsed = {path: Suppressions.parse(src) for path, src in sources.items()}
    for f in findings:
        sup = parsed.get(f.path)
        if sup is None:
            continue
        reason = sup.match(f.rule, (f.line, *f.also_lines))
        if reason is not None:
            f.suppressed = True
            f.suppress_reason = reason
    for path, sup in parsed.items():
        for ln in sup.bare:
            findings.append(Finding(
                rule="WTF000", path=path, line=ln, qualname="<module>",
                message="wtf-lint ignore without a '-- reason' justification"))
    return findings


# ---------------------------------------------------------------- baseline

def load_baseline(path: Path) -> Set[str]:
    if not path.exists():
        return set()
    data = json.loads(path.read_text() or "[]")
    return {entry["key"] for entry in data}


def apply_baseline(findings: List[Finding], keys: Set[str]) -> None:
    for f in findings:
        if not f.suppressed and f.key in keys:
            f.baselined = True


def write_baseline(findings: Sequence[Finding], path: Path) -> None:
    active = [f for f in findings if not f.suppressed]
    path.write_text(json.dumps(
        [{"key": f.key, "note": "grandfathered"} for f in active],
        indent=2) + "\n")


# --------------------------------------------------------------- rendering

def active(findings: Sequence[Finding]) -> List[Finding]:
    return [f for f in findings if not f.suppressed and not f.baselined]


def render_text(findings: Sequence[Finding], root: str) -> str:
    act = active(findings)
    lines: List[str] = []
    by_rule: Dict[str, List[Finding]] = {}
    for f in act:
        by_rule.setdefault(f.rule, []).append(f)
    for rule in sorted(by_rule):
        lines.append(f"{rule}  {RULES.get(rule, '')}")
        for f in sorted(by_rule[rule], key=lambda x: (x.path, x.line)):
            lines.append(f"  {f.path}:{f.line}  [{f.qualname}] {f.message}")
            if f.detail:
                lines.append(f"      {f.detail}")
        lines.append("")
    n_sup = sum(1 for f in findings if f.suppressed)
    n_base = sum(1 for f in findings if f.baselined)
    lines.append(f"{len(act)} finding(s) in {root} "
                 f"({n_sup} suppressed, {n_base} baselined)")
    return "\n".join(lines)


def render_json(findings: Sequence[Finding], root: str) -> str:
    return json.dumps({
        "version": 1,
        "root": root,
        "rules": RULES,
        "counts": {
            "active": len(active(findings)),
            "suppressed": sum(1 for f in findings if f.suppressed),
            "baselined": sum(1 for f in findings if f.baselined),
        },
        "findings": [f.to_json() for f in findings],
    }, indent=2)
