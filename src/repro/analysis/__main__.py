"""CLI: ``python -m repro.analysis src/repro [--format json] [--only WTF002]``.

Exit status is non-zero iff there is at least one active finding (not
inline-suppressed, not baselined) — this is what the ``analysis`` stage of
``scripts/ci.sh`` gates on.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from . import lockspec
from .report import (RULES, apply_baseline, apply_suppressions,
                     active, load_baseline, render_json, render_text,
                     write_baseline)
from .rules import run_rules
from .scanner import scan_paths


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="WTF concurrency invariant analyzer (WTF001-WTF004)")
    ap.add_argument("paths", nargs="+", help="files or directories to scan")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--only", action="append", default=[],
                    metavar="RULE", help="run only these rules "
                    "(repeatable or comma-separated, e.g. WTF002)")
    ap.add_argument("--baseline", default="scripts/lint_baseline.json",
                    help="baseline file of grandfathered finding keys")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline file")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline file from current findings")
    ap.add_argument("--out", metavar="FILE",
                    help="also write the JSON report to FILE")
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument("--show-order", action="store_true",
                    help="print the declared lock order and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid, title in sorted(RULES.items()):
            print(f"{rid}  {title}")
        return 0
    if args.show_order:
        print(lockspec.declared_order_doc())
        return 0

    only = None
    if args.only:
        only = {r.strip().upper() for sel in args.only
                for r in sel.split(",") if r.strip()}
        unknown = only - set(RULES)
        if unknown:
            ap.error(f"unknown rule id(s): {', '.join(sorted(unknown))}")

    mods = scan_paths([Path(p) for p in args.paths])
    findings = run_rules(mods, only=only)
    sources = {str(m.path): m.source for m in mods}
    findings = apply_suppressions(findings, sources)

    baseline_path = Path(args.baseline)
    if args.write_baseline:
        write_baseline(findings, baseline_path)
        print(f"wrote {baseline_path}", file=sys.stderr)
    elif not args.no_baseline:
        apply_baseline(findings, load_baseline(baseline_path))

    root = " ".join(args.paths)
    json_doc = render_json(findings, root)
    if args.out:
        Path(args.out).write_text(json_doc + "\n")
    if args.format == "json":
        print(json_doc)
    else:
        print(render_text(findings, root))
    return 1 if active(findings) else 0


if __name__ == "__main__":
    sys.exit(main())
