"""Concurrency invariant analyzer for the WTF reproduction.

Run as ``python -m repro.analysis src/repro`` (add ``--format json`` for the
machine-readable report, ``--only WTF002`` to iterate on one rule).  The
pass is pure stdlib ``ast`` — no third-party dependencies — and is gated in
CI by the ``analysis`` stage of ``scripts/ci.sh``: any finding that is
neither suppressed inline nor listed in ``scripts/lint_baseline.json``
fails the build.

Declared lock order
-------------------
The global order lives in :mod:`repro.analysis.lockspec` and is shared with
the runtime witness (``repro.core.testing.LockOrderWatchdog``), so the
static declaration and the dynamic behavior can never drift apart.
Outermost first::

    kv.commit_queue < kv.stripe (sorted (shard, stripe))
                    < lease.tables < lease.table
                    < kv.wal < sub.fanin < wlog.consumer < cache.plan
                    < kv.space < storage.files < storage.backing
                    < kv.service

Rule catalog
------------
WTF001  lock-order
    Builds the lock-acquisition graph (which declared locks are held at
    each acquisition site, interprocedurally one level deep through
    same-package calls) and flags (a) acquisitions whose rank is <= an
    already-held rank, (b) same-level multi-acquisition outside a
    ``sorted(...)``-driven loop for ``multi="sorted"`` families, and
    (c) cycles among unranked locks.

WTF002  blocking-under-lock
    Blocking calls (``os.pwrite``/``os.pread``/``os.preadv``/``os.fsync``/
    ``time.sleep``/``open``/executor ``submit``/``result``/``join``/
    ``shutdown``/non-``Condition`` ``.wait``) inside a lock's ``with``
    body.  ``Condition.wait`` is exempt — it releases the lock.  This is
    the PR 7 append-lock bug class.

WTF003  unprotected-shared-write
    In classes that own locks: augmented assignments to ``self.*`` outside
    any lock, plain assignments to attributes written both under and
    outside locks (mixed discipline), and any ``+=`` on a stats-dataclass
    field that bypasses ``AtomicStatsMixin.add()``.  This is the PR 4
    lost-update class.

WTF004  commute-purity
    ``CommutingOp.apply`` implementations that raise, perform I/O or read
    clocks/randomness, read KV/transaction state, or mutate their inputs /
    ``self`` instead of building fresh values ("apply cannot fail", paper
    §2.5); plus ``version_preserving`` ops whose rebuilt region does not
    carry ``end`` through verbatim.

Suppression convention
----------------------
Append ``# wtf-lint: ignore[WTF002] -- one-line justification`` to the
flagged line (or the line directly above it).  Multiple IDs may be listed
comma-separated.  The justification is mandatory: bare ignores are
reported as findings themselves.  ``scripts/lint_baseline.json`` exists for
grandfathered findings and ships empty — prefer a fix or an inline reason.
"""
from __future__ import annotations

from . import lockspec  # noqa: F401  (re-export the shared order spec)

__all__ = ["lockspec"]
