"""Logical-axis sharding (MaxText-style) + declarative param schemas.

Every parameter is declared once as a ``P`` (shape, logical axes, init); the
same schema yields
  * materialized params (`init_params`),
  * abstract params for the AOT dry-run (`abstract_params` —
    ShapeDtypeStruct, no allocation),
  * NamedShardings (`tree_shardings`) via a *rules* table mapping logical
    axes to mesh axes.

Rules compose per-run: TP shards heads/mlp/vocab on "model", FSDP shards the
embed axis of params on "data", EP shards "experts" on "model", SP shards
long sequences on "model".  The multi-pod mesh adds a pure-DP "pod" axis.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

Axes = Tuple[Optional[str], ...]


@dataclass(frozen=True)
class P:
    """Declarative parameter spec."""
    shape: Tuple[int, ...]
    axes: Axes                      # logical axis names, len == len(shape)
    init: str = "normal"            # normal | zeros | ones | scaled
    scale: float = 1.0
    dtype: Optional[str] = None     # override the config param_dtype

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes}")


# Default logical→mesh rules.  None → replicated on that axis.
DEFAULT_RULES: Dict[str, Optional[Union[str, Tuple[str, ...]]]] = {
    "batch": ("pod", "data"),      # activations' batch dim
    "seq": None,                   # sequence (→ "model" under SP)
    "embed": "data",               # FSDP: shard params' embed dim on data
    "embed2": None,                # square-matrix second embed axis
    "heads": "model",              # TP
    "kv_heads": "model",
    "mlp": "model",
    "vocab": "model",
    "experts": "model",            # EP
    "layers": None,                # scan axis — never sharded
    "conv": None,
    "state": None,
    "window": None,                # KV-cache slots (→ "model" under SP)
    "act_embed": None,             # activations' model dim (replicated)
    "act_mlp": "model",            # activations' FFN-hidden dim (TP);
    "act_vocab": "model",          # logits' vocab dim (TP) — separate from
                                   # the weight axes so sequence
                                   # parallelism can unmap them
}


def make_rules(mesh: Mesh, **overrides) -> Dict[str, Any]:
    """Rules valid for ``mesh``: axes absent from the mesh are dropped."""
    rules = dict(DEFAULT_RULES)
    rules.update(overrides)
    names = set(mesh.axis_names)

    def fix(v):
        if v is None:
            return None
        if isinstance(v, tuple):
            kept = tuple(x for x in v if x in names)
            return kept if kept else None
        return v if v in names else None

    return {k: fix(v) for k, v in rules.items()}


def spec_for(axes: Axes, rules: Dict[str, Any]) -> PartitionSpec:
    return PartitionSpec(*(rules.get(a) if a is not None else None
                           for a in axes))


def tree_shardings(schema: Any, mesh: Mesh,
                   rules: Dict[str, Any]) -> Any:
    """NamedSharding tree mirroring a schema/param tree."""
    return jax.tree.map(
        lambda p: NamedSharding(mesh, spec_for(p.axes, rules)),
        schema, is_leaf=lambda x: isinstance(x, P))


def abstract_params(schema: Any, param_dtype: str) -> Any:
    def mk(p: P) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(p.shape, jnp.dtype(p.dtype
                                                       or param_dtype))
    return jax.tree.map(mk, schema, is_leaf=lambda x: isinstance(x, P))


def init_params(schema: Any, rng: jax.Array, param_dtype: str) -> Any:
    """Materialize the schema (host-side; used for smoke tests/examples)."""
    leaves, treedef = jax.tree.flatten(
        schema, is_leaf=lambda x: isinstance(x, P))
    keys = jax.random.split(rng, len(leaves))

    def mk(p: P, key):
        dt = jnp.dtype(p.dtype or param_dtype)
        if p.init == "zeros":
            return jnp.zeros(p.shape, dt)
        if p.init == "ones":
            return jnp.ones(p.shape, dt)
        if p.init == "neg_ones":
            return jnp.full(p.shape, -1, dt)
        if p.init == "neg_large":
            return jnp.full(p.shape, -1e30, dt)
        if p.init == "eps":
            return jnp.full(p.shape, 1e-6, dt)
        if p.init == "scaled":     # fan-in scaled normal
            fan_in = p.shape[-2] if len(p.shape) >= 2 else p.shape[-1]
            return (jax.random.normal(key, p.shape, dt)
                    * (p.scale / np.sqrt(max(1, fan_in))))
        return jax.random.normal(key, p.shape, dt) * 0.02 * p.scale

    return jax.tree.unflatten(
        treedef, [mk(p, k) for p, k in zip(leaves, keys)])


def logical_constraint(x: jax.Array, axes: Axes,
                       rules: Optional[Dict[str, Any]]) -> jax.Array:
    """with_sharding_constraint by logical axes (no-op without rules)."""
    if rules is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(
            x, PartitionSpec(*(rules.get(a) for a in axes)))
    except (ValueError, RuntimeError):
        return x                    # outside a mesh context (smoke tests)
