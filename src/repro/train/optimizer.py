"""AdamW with fp32 moments (params are kept fp32 — the models cast to
bf16 at use, so the params themselves are the master copy).

Functional: `init` builds the moment pytree; `update` is pure and jit-safe.
The moments inherit each parameter's sharding (same tree structure), so
FSDP-sharded params get FSDP-sharded optimizer state — ZeRO-style.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: Optional[float] = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    m: Any
    v: Any
    count: jax.Array


def init(params: Any) -> OptState:
    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(m=zeros,
                    v=jax.tree.map(jnp.zeros_like, zeros),
                    count=jnp.zeros((), jnp.int32))


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay to min_lr_ratio·lr."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(1.0, cfg.decay_steps - cfg.warmup_steps),
                    0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) \
        * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, grads: Any, state: OptState,
           params: Any) -> Tuple[Any, OptState, dict]:
    count = state.count + 1
    gnorm = global_norm(grads)
    if cfg.grad_clip is not None:
        scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    lr = schedule(cfg, count)
    c = count.astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** c
    bc2 = 1 - cfg.b2 ** c

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        step = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) \
            if p.ndim >= 2 else 0.0           # no decay on norms/biases
        new_p = p.astype(jnp.float32) - lr * (step + decay)
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(new_m, new_v, count), metrics
