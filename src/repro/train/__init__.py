from .optimizer import AdamWConfig, OptState
from .step import (TrainHyper, abstract_state, init_state, make_loss_fn,
                   make_prefill_step, make_serve_step, make_train_step)

__all__ = ["AdamWConfig", "OptState", "TrainHyper", "abstract_state",
           "init_state", "make_loss_fn", "make_prefill_step",
           "make_serve_step", "make_train_step"]
