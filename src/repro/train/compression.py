"""int8 gradient/delta compression for the cross-pod (DCN) axis.

The multi-pod mesh's "pod" axis carries one gradient all-reduce per step
over the slowest links.  `compressed_psum_mean` quantizes each leaf to
int8 with a per-leaf scale, sums in int32 across the axis (exact), and
dequantizes — 4× less DCN traffic than fp32 (2× vs bf16) at ~0.4% RMS
error (bounded by q_max=127; validated in tests/test_compression.py).

Used by the trainer's `pod_sync` (local-steps mode: pods run K local steps
and periodically average parameters across pods — the async/elastic
distributed-optimization pattern), and available as a drop-in psum for
explicitly shard_mapped train steps.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

Q_MAX = 127.0


def quantize_int8(x: jax.Array):
    scale = jnp.max(jnp.abs(x.astype(jnp.float32))) / Q_MAX
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                 -Q_MAX, Q_MAX).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def _psum_mean_int8(x, axis_name: str):
    n = jax.lax.psum(1, axis_name)
    q, scale = quantize_int8(x)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    # scales differ per pod: sum of per-pod max-scales bounds the error;
    # use the psum of (q·scale) in int32·fp32 form — exact per-pod dequant
    s_all = jax.lax.all_gather(scale, axis_name)          # [n_pods]
    q_all = jax.lax.all_gather(q, axis_name)              # [n_pods, ...]
    del total
    deq = jnp.tensordot(s_all.astype(jnp.float32),
                        q_all.astype(jnp.float32), axes=(0, 0))
    return (deq / n).astype(x.dtype)


def compressed_psum_mean(tree: Any, axis_name: str) -> Any:
    """Mean of a pytree across `axis_name`, int8 on the wire."""
    return jax.tree.map(
        functools.partial(_psum_mean_int8, axis_name=axis_name), tree)


def make_pod_sync(mesh, compress: bool = True):
    """Parameter averaging across the "pod" axis (local-steps sync).

    Returns a jitted fn tree→tree; identity when the mesh has no pod axis.
    """
    if "pod" not in mesh.axis_names:
        return lambda tree: tree
    from jax.experimental.shard_map import shard_map

    spec_rest = PartitionSpec(*(None for _ in mesh.axis_names))

    def sync_leaf(x):
        def body(lx):
            if compress:
                return _psum_mean_int8(lx, "pod")
            return jax.lax.pmean(lx, "pod")
        return shard_map(body, mesh=mesh, in_specs=PartitionSpec(),
                         out_specs=PartitionSpec(),
                         check_rep=False)(x)

    @jax.jit
    def sync(tree):
        return jax.tree.map(sync_leaf, tree)

    return sync
