"""Host-level training loop: WTF data pipeline + transactional
checkpoint/restart + straggler & elastic hooks.

Fault-tolerance contract (what makes this runnable at 1000+ nodes):
  * The checkpoint manifest atomically carries BOTH the model/optimizer
    state and the data-pipeline cursor — a restarted job can never replay
    or skip data relative to the weights (WTF multi-file transaction).
  * Saves are asynchronous (AsyncCheckpointer) — data writes off the
    critical path, metadata commit at a step barrier.
  * `restore_or_init` makes restart the SAME code path as cold start: the
    trainer is a pure function of (config, filesystem state).
  * Elastic re-scale: `Trainer.with_hosts(n)` re-derives the pipeline for
    a new host count at the same global step (valid because epoch files
    are deterministic), and `CheckpointManager.reshard` re-partitions the
    checkpoint with zero data movement.
  * Straggler mitigation operates at the data layer: shards are handed
    out by deterministic assignment, and any host can serve any record
    range because slices are location-transparent — re-assignment costs
    one metadata read (see DESIGN.md §4).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import AsyncCheckpointer, CheckpointManager
from repro.data.pipeline import DataPipeline, PipelineConfig, PipelineState
from repro.models import Model

from . import optimizer as opt
from .step import TrainHyper, init_state, make_train_step


@dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    keep_checkpoints: int = 3
    log_every: int = 10
    seed: int = 0
    pod_sync_every: int = 0        # >0: local-steps mode w/ pod averaging


class Trainer:
    def __init__(self, model: Model, pipeline: DataPipeline,
                 ckpt: CheckpointManager, hyper: TrainHyper = TrainHyper(),
                 cfg: TrainerConfig = TrainerConfig(),
                 rules=None, pod_sync: Optional[Callable] = None):
        self.model = model
        self.pipeline = pipeline
        self.ckpt = ckpt
        # WtfClient is one-per-thread (it holds open-transaction state):
        # the async checkpoint thread gets its own client on the same
        # cluster, otherwise its commit transaction would interleave with
        # the main thread's data-pipeline reads
        async_mgr = CheckpointManager(ckpt.client.cluster.client(),
                                      ckpt.root, keep=ckpt.keep)
        self.async_ckpt = AsyncCheckpointer(async_mgr)
        self.cfg = cfg
        self.hyper = hyper
        self.pod_sync = pod_sync
        self.train_step = jax.jit(make_train_step(model, hyper, rules),
                                  donate_argnums=(0,))
        self.history: List[Dict[str, float]] = []

    # ------------------------------------------------------------ restart
    def restore_or_init(self):
        """Cold start or restart — one code path, transactional cursor."""
        step = self.ckpt.latest_step()
        if step is None:
            state = init_state(self.model, jax.random.PRNGKey(self.cfg.seed))
            return state, PipelineState()
        man = self.ckpt.read_manifest(step)
        template = init_state(self.model, jax.random.PRNGKey(self.cfg.seed))
        state = self.ckpt.restore(template, step)
        pstate = PipelineState.from_dict(man.get("pipeline", {
            "epoch": 0, "step_in_epoch": 0}))
        return state, pstate

    # ---------------------------------------------------------------- run
    def run(self) -> Dict[str, Any]:
        state, pstate = self.restore_or_init()
        start = int(state["step"])
        self.pipeline.state = pstate
        it = iter(self.pipeline)
        t_last = time.time()
        for step in range(start, self.cfg.total_steps):
            raw = next(it)
            batch = {"tokens": raw["tokens"], "labels": raw["labels"]}
            pstate = self.pipeline.state
            state, metrics = self.train_step(state, batch)
            if self.pod_sync is not None and self.cfg.pod_sync_every \
                    and (step + 1) % self.cfg.pod_sync_every == 0:
                state["params"] = self.pod_sync(state["params"])
            if (step + 1) % self.cfg.log_every == 0 or step == start:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step + 1
                m["steps_per_s"] = self.cfg.log_every \
                    / max(time.time() - t_last, 1e-9)
                t_last = time.time()
                self.history.append(m)
                print(f"[train] step {step + 1}: loss={m['loss']:.4f} "
                      f"lr={m.get('lr', 0):.2e} "
                      f"({m['steps_per_s']:.2f} it/s)", flush=True)
            if (step + 1) % self.cfg.ckpt_every == 0:
                self._save(state, pstate, step + 1)
        self.async_ckpt.wait()
        return {"final_step": self.cfg.total_steps,
                "history": self.history}

    def _save(self, state, pstate: PipelineState, step: int) -> None:
        host_state = jax.tree.map(np.asarray, state)
        self.async_ckpt.save(step, host_state,
                             extra={"pipeline": pstate.to_dict()},
                             prev_step=self.ckpt.latest_step())

    # -------------------------------------------------------------- elastic
    def with_hosts(self, host_id: int, num_hosts: int) -> "Trainer":
        """Elastic re-scale: same global step, new host topology."""
        return Trainer(self.model, self.pipeline.with_hosts(host_id,
                                                            num_hosts),
                       self.ckpt, self.hyper, self.cfg)
