"""train_step / serve_step builders.

`make_train_step` returns a pure function (state, batch) -> (state, metrics)
with optional gradient-accumulation microbatching (a `lax.scan` over
microbatches — activation memory scales with batch/accum_steps while the
gradient buffer stays whole, which is what makes the biggest train cells
fit HBM).  `make_serve_step` returns (params, cache, batch) ->
(next_tokens, cache) — one decoded token against the KV/state cache.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import Model
from repro.models.layers import cross_entropy
from . import optimizer as opt


@dataclass(frozen=True)
class TrainHyper:
    adamw: opt.AdamWConfig = opt.AdamWConfig()
    accum_steps: int = 1            # gradient-accumulation microbatches
    z_loss: float = 0.0             # logit-norm regularizer (0 = off)


def make_loss_fn(model: Model, rules=None) -> Callable:
    cfg = model.cfg

    def loss_fn(params, batch):
        if cfg.moe is not None:
            logits, aux = model.module.forward(params, batch, cfg,
                                               rules=rules, return_aux=True)
        else:
            logits = model.module.forward(params, batch, cfg, rules=rules)
            aux = 0.0
        loss = cross_entropy(logits, batch["labels"])
        return loss + aux, {"ce": loss, "aux": jnp.asarray(aux)}

    return loss_fn


def init_state(model: Model, rng: jax.Array) -> Dict[str, Any]:
    params = model.init(rng)
    return {"params": params, "opt": opt.init(params),
            "step": jnp.zeros((), jnp.int32)}


def abstract_state(model: Model) -> Dict[str, Any]:
    """ShapeDtypeStruct state for the AOT dry-run (no allocation)."""
    params = model.abstract_params()
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)  # noqa: E731
    return {
        "params": params,
        "opt": opt.OptState(m=jax.tree.map(f32, params),
                            v=jax.tree.map(f32, params),
                            count=jax.ShapeDtypeStruct((), jnp.int32)),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def make_train_step(model: Model, hyper: TrainHyper = TrainHyper(),
                    rules=None) -> Callable:
    loss_fn = make_loss_fn(model, rules)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    k = hyper.accum_steps

    def single(params, batch):
        (loss, parts), grads = grad_fn(params, batch)
        return loss, parts, grads

    def accumulated(params, batch):
        def micro(carry, mb):
            acc, loss_acc = carry
            (loss, _), grads = grad_fn(params, mb)
            acc = jax.tree.map(jnp.add, acc, grads)
            return (acc, loss_acc + loss), None

        mbs = jax.tree.map(
            lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]), batch)
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss), _ = jax.lax.scan(micro, (zeros, 0.0), mbs)
        grads = jax.tree.map(lambda g: g / k, grads)
        return loss / k, {}, grads

    def train_step(state, batch):
        if k > 1:
            loss, parts, grads = accumulated(state["params"], batch)
        else:
            loss, parts, grads = single(state["params"], batch)
        params, opt_state, om = opt.update(hyper.adamw, grads,
                                           state["opt"], state["params"])
        metrics = {"loss": loss, **parts, **om}
        return ({"params": params, "opt": opt_state,
                 "step": state["step"] + 1}, metrics)

    return train_step


def make_serve_step(model: Model, rules=None) -> Callable:
    def serve_step(params, cache, batch):
        logits, cache = model.module.decode_step(params, cache, batch,
                                                 model.cfg, rules=rules)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok, cache

    return serve_step


def make_prefill_step(model: Model, rules=None) -> Callable:
    """Full-sequence forward that returns last-position logits (serving
    prefill; decode then continues against the cache built by the engine)."""
    def prefill(params, batch):
        logits = model.module.forward(params, batch, model.cfg,
                                      rules=rules)
        return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)

    return prefill
