#!/usr/bin/env bash
# Tier-1 CI gate: the full pytest suite plus smoke runs of the read and
# write benchmarks (exercise the vectored client, the batched slice-fetch
# scheduler and the write-path store scheduler end to end, printing the
# fetch/store round and coalescing counters).  The write_bench result JSON
# (scalar-vs-batched counter summary) is left in benchmarks/results/ for
# the CI workflow to upload as a build artifact.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
# includes the write-scheduler, fault-injection and interleaving suites
# (tests/test_write_sched.py, test_write_interleavings.py,
# test_fault_tolerance.py)
python -m pytest -x -q

echo "== smoke: read benchmark (vectored vs scalar) =="
timeout "${READ_BENCH_TIMEOUT:-300}" python -m benchmarks.read_bench smoke

echo "== smoke: write benchmark (batched vs scalar stores) =="
timeout "${WRITE_BENCH_TIMEOUT:-300}" python -m benchmarks.write_bench smoke

echo "CI OK"
