#!/usr/bin/env bash
# Tier-1 CI gate: the full pytest suite plus a smoke run of the read
# benchmark (exercises the vectored client + batched slice-fetch scheduler
# end to end and prints the fetch-batch/coalescing counters).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== smoke: read benchmark (vectored vs scalar) =="
timeout "${READ_BENCH_TIMEOUT:-300}" python -m benchmarks.read_bench smoke

echo "CI OK"
