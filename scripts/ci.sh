#!/usr/bin/env bash
# Tier-1 CI gate: the full pytest suite plus smoke runs of the read and
# write benchmarks (exercise the vectored client, the batched slice-fetch
# scheduler and the write-path store scheduler end to end, printing the
# fetch/store round and coalescing counters).  The write_bench result JSON
# (scalar-vs-batched counter summary) is left in benchmarks/results/ for
# the CI workflow to upload as a build artifact.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== preflight: hypothesis present (property tests must run) =="
# conftest.py silently ignores the hypothesis-based suites when the
# package is absent; real CI (workflow sets CI=true and pip-installs
# hypothesis) must never skip them, so fail loudly there instead.  Local
# runs without hypothesis still exercise the seeded variants.
if [ -n "${CI:-}" ]; then
    python -c "import hypothesis; print('hypothesis', hypothesis.__version__)"
else
    python -c "import hypothesis" 2>/dev/null \
        && echo "hypothesis available" \
        || echo "hypothesis absent (property suites run seeded only)"
fi

echo "== analysis: concurrency invariant lints (WTF001-WTF004) =="
# The static pass must be clean (or explicitly baselined) before we spend
# minutes on the suite: a lock-order inversion or blocking-under-lock
# regression fails here in seconds.  The JSON report is left in
# benchmarks/results/ for the CI workflow to upload as a build artifact;
# the human-readable pass prints any findings to the log.
mkdir -p benchmarks/results
python -m repro.analysis src/repro --format json \
    --out benchmarks/results/analysis_report.json
python -m repro.analysis src/repro

echo "== tier-1: pytest =="
# includes the write-scheduler, write-behind, fault-injection and
# interleaving suites (tests/test_write_sched.py, test_write_behind.py,
# test_write_interleavings.py, test_fault_tolerance.py)
python -m pytest -x -q

echo "== smoke: read benchmark (vectored vs scalar, readahead, block cache) =="
# the bench itself hard-asserts: hot block-cached re-reads cost ZERO
# additional storage rounds, and all four readahead x block-cache configs
# return byte-identical streams; the stanza below gates the data-plane
# throughput story on the saved JSON (read_bench.json, uploaded by CI)
timeout "${READ_BENCH_TIMEOUT:-600}" python -m benchmarks.read_bench smoke
python - <<'PY'
import json
r = json.load(open("benchmarks/results/read_bench.json"))
row = r["modes"]["seq"][0]            # 256 KiB sequential: the row where
v, s = row["wtf_vec"], row["wtf"]     # vectoring genuinely batches
# 10% noise floor: best-of-5 wall clocks at this scale are ~10ms and the
# scalar floor jitters run-to-run under CI load; the regression this
# guards (covering-retrieval inversion) measured vectored at 0.65x scalar
assert v["throughput_mbs"] >= 0.9 * s["throughput_mbs"], (
    f"vectored sequential read inverted vs scalar: "
    f"{v['throughput_mbs']:.0f} < 0.9 * {s['throughput_mbs']:.0f} MB/s")
assert s["readahead_hits"] > 0, "sequential scan produced no readahead hits"
assert r["hot_reread"]["rounds_delta"] == 0, r["hot_reread"]
assert r["config_isolation"]["identical"], r["config_isolation"]
print(f"read_bench: vec {v['throughput_mbs']:.0f} vs scalar "
      f"{s['throughput_mbs']:.0f} MB/s, {s['readahead_hits']} readahead "
      f"hits, hot re-read 0 rounds, 4 configs byte-identical OK")
PY

echo "== smoke: write benchmark (batched vs scalar stores) =="
timeout "${WRITE_BENCH_TIMEOUT:-300}" python -m benchmarks.write_bench smoke

echo "== smoke: write benchmark (many small ops, write-behind on/off) =="
# asserts strictly fewer store rounds with the write-behind buffer on
timeout "${WRITE_BENCH_TIMEOUT:-300}" python -m benchmarks.write_bench smoke smallops

echo "== smoke: pipeline overlap (sync vs async prefetch) =="
# asserts async prefetch blocks strictly less, issues no more storage
# rounds over deterministic windows, and hits the plan cache on re-reads;
# leaves pipeline_overlap.json in benchmarks/results/ for CI to upload
timeout "${PIPELINE_BENCH_TIMEOUT:-300}" python -m benchmarks.pipeline_bench smoke overlap

echo "== smoke: metadata-plane fast path (compaction / scatter-gather / group commit) =="
# asserts, with byte-identical reads in every comparison: the hot-region
# stream triggers compactions and resolved-index hits with a bounded
# overlay list; a non-adjacent multi-extent read costs strictly fewer
# storage rounds with retrieve_slices on; and concurrent auto-commit ops
# make strictly fewer KV stripe-lock acquisition passes than commits
# under group commit.  Leaves meta_bench.json for CI to upload.
timeout "${META_BENCH_TIMEOUT:-300}" python -m benchmarks.meta_bench smoke

echo "== smoke: sharded metadata plane (shard sweep 1/2/4, leases off/on) =="
# asserts metadata ops/s increases monotonically with shard count (4-shard
# >= 2x 1-shard under the modeled per-shard service time), lease-enabled
# hot re-reads issue ZERO KV round trips (request counters flat, lease
# hits observed), and every configuration reads back byte-identical to
# the unsharded, lease-off plane.  Covers the "2 shards + leases" config
# the tentpole requires.  Leaves scaling.json for CI to upload.
timeout "${SCALING_BENCH_TIMEOUT:-300}" python -m benchmarks.scaling smoke

echo "== smoke: concurrent appends (§2.5 relative append, O_APPEND fds) =="
# asserts no appended bytes are lost (exact file length), zero OCC
# conflicts among commuting appenders, 2-appender parallel_speedup >= 1.5
# and monotonically non-decreasing appends/s through 8 appenders; leaves
# append_bench.json for CI to upload
timeout "${APPEND_BENCH_TIMEOUT:-300}" python -m benchmarks.append_bench smoke
python - <<'PY'
import json
r = json.load(open("benchmarks/results/append_bench.json"))
assert r["parallel_speedup"] > 1.5, r["parallel_speedup"]
print(f"append_bench parallel_speedup={r['parallel_speedup']:.2f} OK")
PY

echo "== smoke: streaming multi-producer log (wlog) =="
# 4 producers + 3 consumers (one attaching late, via WAL snapshot replay)
# per configuration over metadata shards 1/2 x leases off/on: asserts
# byte-identical delivery across consumers, per-producer FIFO, zero OCC
# conflicts, and an identical record multiset across all configurations;
# leaves wlog_bench.json for CI to upload as a build artifact
timeout "${WLOG_BENCH_TIMEOUT:-300}" python -m benchmarks.wlog_bench smoke

echo "== chaos smoke: kill 1 of N mid-workload, repair to full replication =="
# the §2.9 failure-domain gate: a silent server kill mid-sort-workload
# must lose ZERO bytes (every file byte-compared pre- and post-repair) and
# the repair plane must restore full replication (post-repair region scan);
# leaves repair_bench.json for CI to upload as a build artifact
timeout "${REPAIR_BENCH_TIMEOUT:-300}" python -m benchmarks.run --scale smoke --only repair
python - <<'PY'
import json
r = json.load(open("benchmarks/results/repair_bench.json"))
assert r["data_loss"] == 0, f"chaos smoke lost data: {r['data_loss']} file(s)"
assert r["degraded_read_loss"] == 0, r["degraded_read_loss"]
assert r["replication_restored"] is True, r["extents_after"]
assert r["repair"]["replicas_created"] > 0, r["repair"]
print(f"repair_bench: data_loss=0, replication restored in "
      f"{r['time_to_full_replication_s']:.3f}s "
      f"({r['repair']['replicas_created']} replicas re-created, "
      f"{r['io_health']['servers_skipped']} dead-server probes skipped) OK")
PY

echo "CI OK"
