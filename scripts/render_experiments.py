"""Regenerate the dynamic sections of EXPERIMENTS.md from artifacts.

  PYTHONPATH=src python scripts/render_experiments.py

Replaces the blocks between <!-- BEGIN:x --> / <!-- END:x --> markers:
  roofline_pod, roofline_multipod_delta, dryrun_summary, bench_summary
"""
import json
import re
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.roofline.report import load, markdown_table, terms  # noqa: E402

ROOT = Path(__file__).resolve().parents[1]
RESULTS = ROOT / "benchmarks" / "results"


def dryrun_summary() -> str:
    recs = load("pod") + load("multipod")
    ok = [r for r in recs if r["status"] == "ok"]
    skip = [r for r in recs if r["status"] == "skip"]
    err = [r for r in recs if r["status"] not in ("ok", "skip")]
    lines = [
        f"* cells: **{len(ok)} compiled ok**, {len(skip)} documented "
        f"skips, {len(err)} errors",
        f"* compile time: median "
        f"{sorted(r['compile_s'] for r in ok)[len(ok)//2]:.1f}s, max "
        f"{max(r['compile_s'] for r in ok):.1f}s "
        f"({max(ok, key=lambda r: r['compile_s'])['arch']})",
        f"* largest lowered model: "
        f"{max(r['params'] for r in ok)/1e9:.1f}B params",
    ]
    mems = [r for r in ok if r.get("memory")]
    if mems:
        big = max(mems, key=lambda r: r["memory"]["argument_bytes"])
        lines.append(
            f"* largest per-device state: "
            f"{big['memory']['argument_bytes']/2**30:.2f} GiB arguments "
            f"({big['arch']} × {big['shape']})")
    return "\n".join(lines)


def multipod_delta() -> str:
    pod = {(r["arch"], r["shape"]): r for r in load("pod")
           if r["status"] == "ok"}
    rows = ["| arch | shape | pod coll | multipod coll | Δ (cross-pod) |",
            "|---|---|---|---|---|"]
    for r in load("multipod"):
        if r["status"] != "ok":
            continue
        k = (r["arch"], r["shape"])
        if k not in pod:
            continue
        t1, t2 = terms(pod[k]), terms(r)
        if r["kind"] != "train":
            continue                        # pod axis is pure DP (train)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {t1['collective_s']:.2f}s | "
            f"{t2['collective_s']:.2f}s | "
            f"{t2['collective_s'] - t1['collective_s']:+.2f}s |")
    return "\n".join(rows)


def bench_summary() -> str:
    rows = ["| benchmark | paper anchor | claim | measured | verdict |",
            "|---|---|---|---|---|"]

    def add(name, anchor, claim, measured, ok):
        rows.append(f"| {name} | {anchor} | {claim} | {measured} | "
                    f"{'✅' if ok else '❌'} |")

    try:
        s = json.loads((RESULTS / "sort_mapreduce.json").read_text())
        add("sort I/O", "Table 2",
            "WTF 2R+0W vs conventional 3R+3W",
            f"WTF {s['wtf_read_x']:.2f}R+{s['wtf_write_x']:.2f}W, "
            f"HDFS {s['hdfs_read_x']:.2f}R+{s['hdfs_write_x']:.2f}W",
            abs(s["wtf_read_x"] - 2) < 0.1 and s["wtf_write_x"] < 0.05
            and abs(s["hdfs_read_x"] - 3) < 0.1)
        add("sort wall-clock", "Fig 4", "4× (disk-bound cluster)",
            f"{s['speedup']:.2f}× (in-memory container)",
            s["speedup"] > 1.2)
        if "keyonly_read_x" in s:
            add("key-only sort", "beyond paper",
                "bucket+sort need only the 10 B keys",
                f"R={s['keyonly_read_x']:.4f}×, W=0×, "
                f"{s['keyonly_speedup']:.2f}× vs HDFS",
                s["keyonly_read_x"] < 0.01)
        wtf_pct = (s["wtf"]["stages_s"].get("merging", 0)
                   / max(s["wtf"]["total_s"], 1e-9))
        hdfs_merge = s["hdfs"]["stages_s"].get("merging", 1e-9)
        vs_hdfs = s["wtf"]["stages_s"].get("merging", 0) / hdfs_merge
        add("concat share", "Fig 5 (<1% runtime)",
            "concat ≪ data-moving merge (metadata-time; share is "
            "scale-dependent)",
            f"{wtf_pct*100:.1f}% of WTF sort; {vs_hdfs*100:.0f}% of the "
            "HDFS merge stage", vs_hdfs < 0.2)
    except FileNotFoundError:
        pass
    try:
        s = json.loads((RESULTS / "seq_write.json").read_text())
        worst = min(r["wtf_vs_hdfs"] for r in s["write_sizes"])
        big = min(r["wtf_vs_hdfs"] for r in s["write_sizes"]
                  if r["write_size"] >= 1 << 20)
        add("seq write", "Fig 7", "WTF ≥84% of HDFS (84% floor @256 KB)",
            f"{worst:.2f} @256 KB, {big:.2f} @≥1 MB", big > 0.84)
    except FileNotFoundError:
        pass
    try:
        s = json.loads((RESULTS / "random_write.json").read_text())
        worst = min(r["random_vs_seq"] for r in s["write_sizes"])
        add("random write", "Fig 9", "within 2× of sequential",
            f"min ratio {worst:.2f} (HDFS: unsupported)", worst > 0.5)
    except FileNotFoundError:
        pass
    try:
        s = json.loads((RESULTS / "read_bench.json").read_text())
        worst = min(r["wtf_vs_hdfs"] for r in s["modes"]["seq"])
        big = min(r["wtf_vs_hdfs"] for r in s["modes"]["seq"]
                  if r["read_size"] >= 1 << 20)
        rnd = max(r["wtf_vs_hdfs"] for r in s["modes"]["random"])
        add("seq read", "Fig 11", "WTF ≥80% of HDFS",
            f"{worst:.2f} @256 KB, {big:.2f} @≥1 MB", big > 0.7)
        add("random read", "Fig 12", "WTF up to 2.4× HDFS (small reads)",
            f"best ratio {rnd:.2f}", rnd > 1.0)
    except FileNotFoundError:
        pass
    try:
        s = json.loads((RESULTS / "scaling.json").read_text())
        add("client scaling", "Figs 13-14",
            "throughput saturates with clients",
            f"{s['rows'][0]['throughput_mbs']:.0f}→"
            f"{s['rows'][-1]['throughput_mbs']:.0f} MB/s "
            f"({s['rows'][0]['clients']}→{s['rows'][-1]['clients']} "
            "clients)", s["saturates"])
    except FileNotFoundError:
        pass
    try:
        s = json.loads((RESULTS / "gc_bench.json").read_text())
        r0, r1 = s["rows"][0], s["rows"][-1]
        add("GC rate", "Fig 15", "rate rises with garbage fraction",
            f"{r0['rate_mbs']:.0f} MB/s @{int(r0['garbage_fraction']*100)}%"
            f" → {r1['rate_mbs']:.0f} MB/s "
            f"@{int(r1['garbage_fraction']*100)}%",
            r1["rate_mbs"] > r0["rate_mbs"])
    except FileNotFoundError:
        pass
    try:
        s = json.loads((RESULTS / "append_bench.json").read_text())
        add("relative appends", "§2.5", "concurrent appends don't conflict",
            f"{s['rows'][-1]['appenders']} appenders: "
            f"{s['rows'][-1]['kv_conflicts']} kv conflicts, "
            f"{s['parallel_speedup']:.2f}× vs 1",
            s["rows"][-1]["kv_conflicts"] < 100)
    except FileNotFoundError:
        pass
    try:
        s = json.loads((RESULTS / "pipeline_bench.json").read_text())
        add("zero-copy shuffle", "beyond paper",
            "epoch shuffle moves ~0 data bytes",
            f"{s['shuffle']['data_bytes_moved']} B moved for "
            f"{s['shuffle']['naive_bytes']//2**20} MiB naive",
            s["shuffle"]["data_bytes_moved"]
            < 0.01 * s["shuffle"]["naive_bytes"])
        add("zero-copy reshard", "beyond paper",
            "checkpoint reshard is metadata-time",
            f"{s['checkpoint']['reshard_data_bytes']} B moved",
            s["checkpoint"]["reshard_data_bytes"] < 1 << 20)
    except FileNotFoundError:
        pass
    return "\n".join(rows)


def inject(text: str, name: str, content: str) -> str:
    pat = re.compile(rf"(<!-- BEGIN:{name} -->).*?(<!-- END:{name} -->)",
                     re.S)
    return pat.sub(lambda m: f"{m.group(1)}\n{content}\n{m.group(2)}",
                   text)


def main():
    path = ROOT / "EXPERIMENTS.md"
    text = path.read_text()
    text = inject(text, "roofline_pod", markdown_table("pod"))
    text = inject(text, "roofline_multipod_delta", multipod_delta())
    text = inject(text, "dryrun_summary", dryrun_summary())
    text = inject(text, "bench_summary", bench_summary())
    path.write_text(text)
    print("EXPERIMENTS.md regenerated")


if __name__ == "__main__":
    main()
