"""End-to-end training driver: train a small LM for a few hundred steps
with the full substrate — WTF-backed data pipeline (zero-copy epoch
shuffles), transactional async checkpointing, restart-safe cursor.

  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--arch smollm-360m]

Demonstrates crash-restart: the run checkpoints every 50 steps; re-running
the same command resumes from the latest checkpoint with the data cursor
exactly where the weights are.
"""
import argparse
import shutil
import sys
import tempfile

sys.path.insert(0, __file__.rsplit("/", 2)[0] + "/src")

import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_smoke_config
from repro.core import Cluster
from repro.data.pipeline import DataPipeline, PipelineConfig
from repro.data.records import write_token_shard
from repro.models import get_model
from repro.train import AdamWConfig, TrainHyper
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--data-dir", default=None,
                    help="persist the WTF cluster here to test restart")
    args = ap.parse_args()

    data_dir = args.data_dir or tempfile.mkdtemp(prefix="wtf_train_")
    cluster = Cluster(n_servers=4, data_dir=data_dir, replication=2,
                      region_size=4 << 20)
    fs = cluster.client()

    # ---- synthetic corpus as a WTF token shard (structured so loss falls)
    cfg = get_smoke_config(args.arch).replace(max_seq=args.seq)
    model = get_model(cfg)
    rng = np.random.RandomState(0)
    n_tokens = args.batch * (args.seq + 1) * 64
    # a repeating Markov-ish stream: next token = (tok * 31 + noise) % vocab
    toks = np.zeros(n_tokens, np.int32)
    for i in range(1, n_tokens):
        toks[i] = (toks[i - 1] * 31 + 7 + (rng.randint(3) == 0)) % cfg.vocab
    if not fs.exists("/corpus"):
        fs.mkdir("/corpus")
        write_token_shard(fs, "/corpus/shard0", iter(toks), args.seq + 1)

    pipe = DataPipeline(fs, PipelineConfig(
        src_paths=("/corpus/shard0",), work_dir="/epochs",
        block_tokens=args.seq + 1, global_batch=args.batch, seed=0))
    ckpt = CheckpointManager(fs, "/ckpt", keep=3)
    trainer = Trainer(
        model, pipe, ckpt,
        hyper=TrainHyper(adamw=AdamWConfig(lr=1e-3, warmup_steps=20,
                                           decay_steps=args.steps)),
        cfg=TrainerConfig(total_steps=args.steps, ckpt_every=50,
                          log_every=10))
    resumed_from = ckpt.latest_step()
    if resumed_from:
        print(f"[train_lm] resuming from step {resumed_from}")
    out = trainer.run()
    first, last = out["history"][0]["loss"], out["history"][-1]["loss"]
    print(f"[train_lm] loss {first:.3f} -> {last:.3f} "
          f"over {args.steps} steps "
          f"({'improved' if last < first else 'NOT improved'})")
    if not args.data_dir:
        cluster.close()
        shutil.rmtree(data_dir, ignore_errors=True)
    return 0 if last < first else 1


if __name__ == "__main__":
    sys.exit(main())
