"""The paper's flagship application (§4.1): map-reduce sort via file
slicing vs the conventional read-rewrite pipeline.

  PYTHONPATH=src python examples/mapreduce_sort.py [--mb 64]
"""
import argparse
import sys

sys.path.insert(0, __file__.rsplit("/", 2)[0] + "/src")
sys.path.insert(0, __file__.rsplit("/", 2)[0])

from benchmarks.common import Scale
from benchmarks.sort_mapreduce import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mb", type=int, default=64)
    args = ap.parse_args()
    scale = Scale(total_bytes=args.mb << 20)
    run(scale)


if __name__ == "__main__":
    main()
