"""WTF quickstart: the transactional filesystem + file-slicing API tour.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys
import tempfile

sys.path.insert(0, __file__.rsplit("/", 2)[0] + "/src")

from repro.core import Cluster


def main():
    with tempfile.TemporaryDirectory() as d:
        cluster = Cluster(n_servers=4, data_dir=d, replication=2)
        fs = cluster.client()

        # --- POSIX surface -------------------------------------------------
        fs.mkdir("/demo")
        fd = fs.open("/demo/a", "w")
        fs.write(fd, b"hello slicing world")
        fs.close(fd)
        print("read back:", fs.pread(fs.open("/demo/a", "r"), 19, 0))

        # --- multi-file transaction (§2.6) ---------------------------------
        with fs.transaction():
            f1 = fs.open("/demo/x", "w")
            f2 = fs.open("/demo/y", "w")
            fs.write(f1, b"both files commit")
            fs.write(f2, b"or neither does")
            fs.close(f1)
            fs.close(f2)
        print("txn files:", fs.listdir("/demo"))

        # --- file slicing: rearrange without moving data (§2.5) ------------
        fd = fs.open("/demo/a", "r")
        fs.seek(fd, 6)
        slices = fs.yank(fd, 7)            # "slicing"
        fs.close(fd)
        out = fs.open("/demo/sliced", "w")
        fs.paste(out, slices)              # zero data bytes moved
        fs.paste(out, slices)
        fs.close(out)
        print("sliced file:", fs.pread(fs.open("/demo/sliced", "r"), 14, 0))

        # --- concat is pure metadata ----------------------------------------
        before = cluster.total_stats()["data_bytes_written"]
        fs.concat(["/demo/a", "/demo/sliced"], "/demo/cat")
        moved = cluster.total_stats()["data_bytes_written"] - before
        print(f"concat moved {moved} data bytes "
              f"(file is {fs.file_length('/demo/cat')} bytes)")

        # --- survive a storage-server failure (§2.9, replication=2) --------
        cluster.fail_server(0)
        print("after server failure:",
              fs.pread(fs.open("/demo/a", "r"), 19, 0))
        cluster.close()


if __name__ == "__main__":
    main()
