"""Batched serving with the paged KV engine: continuous batching, prefix
sharing (WTF `copy` on KV pages), and the Pallas paged-attention kernel.

  PYTHONPATH=src python examples/serve_lm.py
"""
import sys
import time

sys.path.insert(0, __file__.rsplit("/", 2)[0] + "/src")

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import get_model
from repro.serving.engine import Engine, EngineConfig


def main():
    cfg = get_smoke_config("qwen2-7b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params,
                 EngineConfig(page_tokens=8, num_pages=512))

    rng = np.random.RandomState(0)
    system_prompt = rng.randint(0, cfg.vocab, 24).astype(np.int32)

    # eight requests sharing the same 24-token system prompt: the shared
    # pages are forked (refcounted), not copied
    base = eng.add(system_prompt, max_new=8)
    t0 = time.time()
    sids = [base]
    for i in range(7):
        user = rng.randint(0, cfg.vocab, 8).astype(np.int32)
        sids.append(eng.add(np.concatenate([system_prompt, user]),
                            max_new=8, fork_from=base))
    steps = 0
    while any(not eng._requests[s].done for s in sids):
        eng.step()
        steps += 1
    dt = time.time() - t0
    stats = eng.cache.stats
    print(f"[serve] 8 requests × 8 tokens in {steps} batched steps, "
          f"{dt:.2f}s")
    print(f"[serve] pages: allocated={stats['pages_allocated']} "
          f"shared={stats['pages_shared']} cow={stats['pages_copied']}")
    for s in sids[:3]:
        print(f"[serve] seq {s}: {eng.result(s)}")
    total_tokens = sum(len(eng.result(s)) for s in sids)
    print(f"[serve] throughput: {total_tokens / dt:.1f} tok/s "
          f"(CPU, interpret-mode kernel)")


if __name__ == "__main__":
    main()
