"""Repo-root pytest config: put ``src/`` on sys.path so ``python -m pytest``
works without the ``PYTHONPATH=src`` incantation, and skip test modules whose
optional third-party deps are absent in this container."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "src"))

# Run the whole tier-1 suite under the runtime lock-order witness: core
# modules wrap their locks via repro.core.testing.witness_lock, so any
# acquisition against the declared order (repro.analysis.lockspec) raises
# LockOrderViolation at acquisition time instead of deadlocking.  Set
# WTF_LOCK_WITNESS=0 to opt out.
os.environ.setdefault("WTF_LOCK_WITNESS", "1")

collect_ignore = []
try:
    import hypothesis  # noqa: F401
except ImportError:
    collect_ignore += [
        "tests/test_fs_properties.py",
        "tests/test_overlay_property.py",
        "tests/test_slicing.py",
    ]
