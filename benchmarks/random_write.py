"""Random-offset writes (Figs 9-10).  HDFS cannot express this workload at
all (the paper's point) — WTF's sequential write is the baseline."""
from __future__ import annotations

import threading
import time
from typing import List

import numpy as np

from .common import (Scale, fmt_bytes, lat_summary, save_result,
                     wtf_cluster, wtf_io)

WRITE_SIZES = [256 << 10, 1 << 20, 4 << 20]


def run(scale: Scale) -> dict:
    out = {"write_sizes": [], "scale": scale.name}
    file_bytes = scale.total_bytes // scale.n_clients
    for ws in WRITE_SIZES:
        row = {"write_size": ws}
        for mode in ("seq", "random"):
            with wtf_cluster(scale) as cluster:
                clients = [cluster.client()
                           for _ in range(scale.n_clients)]
                # preallocate files so random offsets land inside
                for i, c in enumerate(clients):
                    fd = c.open(f"/f{i}", "w")
                    c.write(fd, b"\0" * file_bytes)
                    c.close(fd)
                cluster.reset_io_stats()
                lats: List[List[float]] = [[] for _ in clients]

                def work(i):
                    c = clients[i]
                    fd = c.open(f"/f{i}", "r+")   # overwrite, no truncate
                    rng = np.random.RandomState(i)
                    buf = b"r" * ws
                    n = file_bytes // ws
                    for j in range(n):
                        off = (j * ws if mode == "seq" else
                               int(rng.randint(0, max(1, file_bytes - ws))))
                        t0 = time.perf_counter()
                        c.pwrite(fd, buf, off)
                        lats[i].append(time.perf_counter() - t0)
                    c.close(fd)

                threads = [threading.Thread(target=work, args=(i,))
                           for i in range(len(clients))]
                t0 = time.perf_counter()
                for t in threads:
                    t.start()
                for t in threads:
                    t.join()
                secs = time.perf_counter() - t0
                io = wtf_io(cluster)
                row[mode] = {
                    "throughput_mbs": io["bytes_written"] / secs / 1e6,
                    **lat_summary([x for l in lats for x in l])}
        row["random_vs_seq"] = (row["random"]["throughput_mbs"]
                                / max(row["seq"]["throughput_mbs"], 1e-9))
        out["write_sizes"].append(row)
        print(f"[random_write] {fmt_bytes(ws)}: seq "
              f"{row['seq']['throughput_mbs']:.0f} MB/s | random "
              f"{row['random']['throughput_mbs']:.0f} MB/s | ratio "
              f"{row['random_vs_seq']:.2f} (paper: ≥0.5)")
    save_result("random_write", out)
    return out


if __name__ == "__main__":
    run(Scale.of("quick"))
