"""Shared benchmark scaffolding: clusters, timing, I/O accounting, result
persistence.

The paper's 15-node/100 GB experiments scale to the container via
`--scale`: bytes moved is the primary metric (hardware-independent, exactly
Table 2's accounting), wall-clock is secondary.
"""
from __future__ import annotations

import json
import shutil
import statistics
import tempfile
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Dict, List, Optional

from repro.baselines import HdfsLikeCluster
from repro.core import Cluster
from repro.core.iosched import DEFAULT_MAX_GAP
from repro.core.wsched import DEFAULT_MAX_COALESCE

RESULTS_DIR = Path(__file__).resolve().parent / "results"

# Results schema: bump when a benchmark's JSON shape changes so trajectory
# tooling can evolve without guessing.  v2 added the field itself.
RESULTS_SCHEMA_VERSION = 2


@dataclass
class Scale:
    """smoke: seconds (CI gate); quick: CI-sized; full: a few GB (still
    minutes, not hours)."""
    name: str = "quick"
    total_bytes: int = 64 << 20
    record_bytes: int = 64 << 10
    key_bytes: int = 10
    n_servers: int = 4
    n_clients: int = 4
    region_size: int = 4 << 20
    block_size: int = 4 << 20          # HDFS-like block (paper: 64 MB)

    @staticmethod
    def of(name: str) -> "Scale":
        if name not in ("smoke", "quick", "full"):
            raise ValueError(
                f"unknown scale {name!r}: choose smoke, quick, or full")
        if name == "full":
            return Scale("full", total_bytes=1 << 30,
                         record_bytes=512 << 10, n_servers=8, n_clients=8,
                         region_size=16 << 20, block_size=16 << 20)
        if name == "smoke":
            # record_bytes stays >= 64 KiB: key-only sort reads 10-byte
            # keys one record apart, and the scheduler's 32 KiB gap cap
            # must NOT coalesce across records or the "read ~0.03% of the
            # data" accounting premise breaks
            return Scale("smoke", total_bytes=8 << 20,
                         record_bytes=64 << 10, n_servers=2, n_clients=2,
                         region_size=1 << 20, block_size=1 << 20)
        return Scale()


class Timer:
    def __init__(self):
        self.laps: Dict[str, float] = {}

    @contextmanager
    def lap(self, name: str):
        t0 = time.perf_counter()
        yield
        self.laps[name] = self.laps.get(name, 0.0) \
            + time.perf_counter() - t0

    @property
    def total(self) -> float:
        return sum(self.laps.values())


@contextmanager
def wtf_cluster(scale: Scale, replication: int = 1, **cluster_kw):
    d = tempfile.mkdtemp(prefix="wtf_bench_")
    # Benchmarks PIN the historical 32 KiB gap/pack thresholds (the
    # library default is now adaptive): the paper-reproduction accounting
    # — e.g. the sort benchmark's premise that key-only reads of 64 KiB
    # records never coalesce across records — must stay comparable run
    # over run and PR over PR.  Pass explicit knobs to override.
    cluster_kw.setdefault("fetch_gap_bytes", DEFAULT_MAX_GAP)
    cluster_kw.setdefault("store_coalesce_bytes", DEFAULT_MAX_COALESCE)
    c = Cluster(n_servers=scale.n_servers, data_dir=d,
                replication=replication, region_size=scale.region_size,
                **cluster_kw)
    try:
        yield c
    finally:
        c.close()
        shutil.rmtree(d, ignore_errors=True)


@contextmanager
def hdfs_cluster(scale: Scale, replication: int = 1):
    d = tempfile.mkdtemp(prefix="hdfs_bench_")
    c = HdfsLikeCluster(n_servers=scale.n_servers, data_dir=d,
                        replication=replication,
                        block_size=scale.block_size)
    try:
        yield c
    finally:
        c.close()
        shutil.rmtree(d, ignore_errors=True)


def wtf_io(cluster: Cluster) -> Dict[str, int]:
    s = cluster.total_stats()
    return {"bytes_read": s["data_bytes_read"],
            "bytes_written": s["data_bytes_written"]}


def percentile(xs: List[float], p: float) -> float:
    if not xs:
        return 0.0
    xs = sorted(xs)
    i = min(len(xs) - 1, int(p / 100 * len(xs)))
    return xs[i]


def lat_summary(lat_s: List[float]) -> dict:
    return {
        "median_ms": percentile(lat_s, 50) * 1e3,
        "p5_ms": percentile(lat_s, 5) * 1e3,
        "p95_ms": percentile(lat_s, 95) * 1e3,
        "p99_ms": percentile(lat_s, 99) * 1e3,
        "mean_ms": (statistics.mean(lat_s) * 1e3) if lat_s else 0.0,
        "n": len(lat_s),
    }


def save_result(name: str, payload: dict) -> Path:
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    path = RESULTS_DIR / f"{name}.json"
    payload = {"schema_version": RESULTS_SCHEMA_VERSION, **payload}
    path.write_text(json.dumps(payload, indent=1, default=str))
    return path


def fmt_bytes(n: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(n) < 1024:
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}PB"
