"""Sequential write throughput/latency vs write size (Figs 7-8)."""
from __future__ import annotations

import threading
import time
from typing import List

from .common import (Scale, fmt_bytes, hdfs_cluster, lat_summary,
                     save_result, wtf_cluster, wtf_io)

WRITE_SIZES = [256 << 10, 1 << 20, 4 << 20]


def _drive_writers(n_clients, total_bytes, write_size, mk_writer):
    """Concurrent fixed-size sequential writers; returns (s, latencies)."""
    per_client = total_bytes // n_clients
    lats: List[List[float]] = [[] for _ in range(n_clients)]

    def work(i):
        write = mk_writer(i)
        done = 0
        buf = b"w" * write_size
        while done < per_client:
            t0 = time.perf_counter()
            write(buf)
            lats[i].append(time.perf_counter() - t0)
            done += write_size

    threads = [threading.Thread(target=work, args=(i,))
               for i in range(n_clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return time.perf_counter() - t0, [x for l in lats for x in l]


def run(scale: Scale) -> dict:
    out = {"write_sizes": [], "scale": scale.name}
    for ws in WRITE_SIZES:
        row = {"write_size": ws}
        with wtf_cluster(scale) as cluster:
            clients = [cluster.client() for _ in range(scale.n_clients)]
            fds = [c.open(f"/w{i}", "w") for i, c in enumerate(clients)]

            def wtf_writer(i):
                return lambda buf: clients[i].write(fds[i], buf)

            secs, lats = _drive_writers(scale.n_clients, scale.total_bytes,
                                        ws, wtf_writer)
            io = wtf_io(cluster)
            row["wtf"] = {"throughput_mbs": io["bytes_written"] / secs / 1e6,
                          "wall_s": secs, **lat_summary(lats)}
        with hdfs_cluster(scale) as cluster:
            fs = cluster.client()
            writers = [fs.create(f"/w{i}")
                       for i in range(scale.n_clients)]

            def hdfs_writer(i):
                def w(buf):
                    writers[i].write(buf)
                    writers[i].hflush()     # paper's parity setting
                return w

            secs, lats = _drive_writers(scale.n_clients, scale.total_bytes,
                                        ws, hdfs_writer)
            io = cluster.io_stats()
            row["hdfs"] = {"throughput_mbs": io["bytes_written"] / secs / 1e6,
                           "wall_s": secs, **lat_summary(lats)}
        row["wtf_vs_hdfs"] = (row["wtf"]["throughput_mbs"]
                              / max(row["hdfs"]["throughput_mbs"], 1e-9))
        out["write_sizes"].append(row)
        print(f"[seq_write] {fmt_bytes(ws)}: WTF "
              f"{row['wtf']['throughput_mbs']:.0f} MB/s "
              f"(med {row['wtf']['median_ms']:.1f}ms) | HDFS "
              f"{row['hdfs']['throughput_mbs']:.0f} MB/s "
              f"(med {row['hdfs']['median_ms']:.1f}ms) | ratio "
              f"{row['wtf_vs_hdfs']:.2f} (paper: ≥0.84)")
    save_result("seq_write", out)
    return out


if __name__ == "__main__":
    run(Scale.of("quick"))
