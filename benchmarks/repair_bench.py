"""Chaos benchmark: kill 1 of N servers mid-workload, prove zero data loss
and measure time-to-full-replication (§2.9 + the repair plane).

The scenario is the paper's availability claim made falsifiable:

  1. a sort-style record workload writes files across the cluster
     (replication=2, 4 servers);
  2. ONE server is killed silently — no coordinator notification, exactly
     a node death — while the workload is still writing;
  3. the remaining writes and a full read-back run against the degraded
     cluster (failover + health tracker route around the corpse);
  4. the repair daemon re-replicates everything the dead server held and
     ``verify()`` scans region metadata until every visible extent is back
     at full replication — that wall-clock is ``time_to_full_replication_s``;
  5. every file is byte-compared against the expected contents:
     ``data_loss`` is the number of files that differ (must be 0), and the
     health/hedge/repair counters from ``Cluster.total_stats()`` land in
     the JSON payload for the CI chaos gate.
"""
from __future__ import annotations

import dataclasses
import time

from repro.core.repair import RepairDaemon
from repro.core.testing import kill_server

from .common import Scale, Timer, fmt_bytes, save_result, wtf_cluster

REPLICATION = 2
KILL_SID = 1          # any server: placement spreads every workload over all


def _record(i: int, record_bytes: int) -> bytes:
    return (b"%010d" % i) * (record_bytes // 10) + b"x" * (record_bytes % 10)


def run(scale: Scale) -> dict:
    # The failure domain needs spare capacity: ensure >= 4 servers so
    # killing one still leaves enough ring successors for 2 replicas.
    if scale.n_servers < 4:
        scale = dataclasses.replace(scale, n_servers=4)
    n_records = max(8, scale.total_bytes // scale.record_bytes // 8)
    record_bytes = scale.record_bytes
    timer = Timer()
    with wtf_cluster(scale, replication=REPLICATION) as c:
        cl = c.client()
        expected = {}

        def write(i: int) -> None:
            data = _record(i, record_bytes)
            path = f"/rec/{i:06d}"
            with cl.open_file(path, "w") as f:
                f.write(data)
            expected[path] = data

        cl.mkdir("/rec")
        half = n_records // 2
        with timer.lap("write_before_kill"):
            for i in range(half):
                write(i)
        # --- the chaos event: silent node death mid-workload -------------
        kill_server(c, KILL_SID)
        with timer.lap("write_after_kill"):
            for i in range(half, n_records):
                write(i)
        with timer.lap("read_degraded"):
            degraded_loss = 0
            for path, data in expected.items():
                with cl.open_file(path, "r") as f:
                    if f.read() != data:
                        degraded_loss += 1
        # --- repair: tickets first, then scan until verify is clean ------
        daemon = RepairDaemon(c)
        pre = daemon.verify()
        t0 = time.perf_counter()
        with timer.lap("repair"):
            daemon.repair_pass(full_scan=False)      # fresh-damage tickets
            passes = 1
            while not daemon.verify()["replication_restored"]:
                daemon.repair_pass(full_scan=True)   # pre-queue damage
                passes += 1
                if passes > 10:
                    break
        time_to_full = time.perf_counter() - t0
        post = daemon.verify()
        # --- acceptance: byte-identical read-back of every file ----------
        with timer.lap("read_after_repair"):
            data_loss = 0
            cl2 = c.client()                         # cold caches
            for path, data in expected.items():
                with cl2.open_file(path, "r") as f:
                    if f.read() != data:
                        data_loss += 1
        stats = c.total_stats()
        payload = {
            "benchmark": "repair_bench",
            "n_servers": scale.n_servers,
            "replication": REPLICATION,
            "killed_server": KILL_SID,
            "n_records": n_records,
            "record_bytes": record_bytes,
            "data_loss": data_loss,
            "degraded_read_loss": degraded_loss,
            "replication_restored": post["replication_restored"],
            "time_to_full_replication_s": time_to_full,
            "repair_passes": passes,
            "extents_before": pre,
            "extents_after": post,
            "laps_s": timer.laps,
            "io_health": stats["io_health"],
            "repair": stats["repair"],
            "degraded_stores": stats["degraded_stores"],
        }
    save_result("repair_bench", payload)
    print(f"  wrote {n_records} x {fmt_bytes(record_bytes)} records, "
          f"killed server {KILL_SID} mid-workload")
    print(f"  degraded reads: {degraded_loss} mismatches; "
          f"under-replicated before repair: {pre['under_replicated']}")
    print(f"  repair: {payload['repair']['replicas_created']} replicas "
          f"re-created ({fmt_bytes(payload['repair']['bytes_recopied'])}) "
          f"in {passes} pass(es), "
          f"time_to_full_replication={time_to_full:.3f}s")
    print(f"  data_loss={data_loss} "
          f"replication_restored={post['replication_restored']}")
    if data_loss or not post["replication_restored"]:
        raise AssertionError(
            f"chaos gate failed: data_loss={data_loss}, "
            f"replication_restored={post['replication_restored']}")
    return payload
