"""Scalar vs. batched write-path comparison — the sibling of ``read_bench``.

The same byte ranges are written twice over identical clusters: once as one
scalar ``pwrite`` per chunk (one synchronous store round per slice, serially
per replica — the pre-scheduler pipeline) and once as ``pwritev`` batches
routed through the write scheduler (``wsched``): per-(server, backing-file)
grouping, covering coalescing of small chunks, concurrent replica fan-out.

Reported per row, from ``ClientStats`` and the servers' ``StorageStats``:

  * ``store_batches``   — store rounds actually issued (the cost metric);
  * ``slices_store_coalesced`` — slice creations folded into shared rounds;
  * ``slices_written`` / ``slices_created`` — server-side logical slices
    vs. rounds accepted.

The acceptance gauge of the write scheduler: a batched run must issue
FEWER per-server store round-trips than the scalar run over identical
chunks (``store_batches`` < scalar ``slices_written``).

A second scenario, **many-small-ops**, measures the write-behind buffer:
each client issues many small ``pwrite`` ops under ONE transaction — a
directory-entry-append / manifest / record-at-a-time shape where every op
is its own store round without buffering.  The same sequence runs with
``Cluster(write_behind=...)`` off and on; with the buffer the whole
transaction flushes as one scheduled pass (``writeback_flushes``,
``slices_cross_op_coalesced``) and MUST issue strictly fewer store rounds.

Usage: ``python -m benchmarks.write_bench [smoke|quick|full]
[vectored|smallops|all]`` (default: vectored, the original comparison).
The small-ops scenario saves its counters to
``results/write_bench_smallops.json``.
"""
from __future__ import annotations

import sys
import threading
import time
from typing import List

import numpy as np

from .common import (Scale, fmt_bytes, lat_summary, save_result, wtf_cluster,
                     wtf_io)

WRITE_SIZES = [64 << 10, 256 << 10, 1 << 20]
VEC_BATCH = 16                       # chunks per pwritev call
SMALL_WRITE = 1 << 10                # many-small-ops scenario: 1 KiB ops
SMALL_OPS = {"smoke": 48, "quick": 128, "full": 256}


def _chunks(i: int, file_bytes: int, write_size: int) -> List[bytes]:
    rng = np.random.RandomState(i)
    n = max(1, file_bytes // write_size)
    return [rng.bytes(write_size) for _ in range(n)]


def _drive_scalar(cluster, scale, write_size, file_bytes):
    """One pwrite per chunk — one store round per slice."""
    clients = [cluster.client() for _ in range(scale.n_clients)]
    fds = [c.open(f"/w{i}", "w") for i, c in enumerate(clients)]
    lats: List[List[float]] = [[] for _ in range(scale.n_clients)]

    def work(i):
        off = 0
        for chunk in _chunks(i, file_bytes, write_size):
            t0 = time.perf_counter()
            clients[i].pwrite(fds[i], chunk, off)
            lats[i].append(time.perf_counter() - t0)
            off += len(chunk)

    secs = _run_threads(work, scale.n_clients)
    return clients, secs, [x for l in lats for x in l]


def _drive_batched(cluster, scale, write_size, file_bytes):
    """The same chunks issued as pwritev batches of VEC_BATCH."""
    clients = [cluster.client() for _ in range(scale.n_clients)]
    fds = [c.open(f"/w{i}", "w") for i, c in enumerate(clients)]
    lats: List[List[float]] = [[] for _ in range(scale.n_clients)]

    def work(i):
        chunks = _chunks(i, file_bytes, write_size)
        off = 0
        for j in range(0, len(chunks), VEC_BATCH):
            batch = chunks[j:j + VEC_BATCH]
            t0 = time.perf_counter()
            clients[i].pwritev(fds[i], batch, off)
            # amortized per-chunk latency, comparable with the scalar row
            lats[i].append((time.perf_counter() - t0) / len(batch))
            off += sum(len(b) for b in batch)

    secs = _run_threads(work, scale.n_clients)
    return clients, secs, [x for l in lats for x in l]


def _run_threads(work, n) -> float:
    threads = [threading.Thread(target=work, args=(i,)) for i in range(n)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return time.perf_counter() - t0


def _row_stats(cluster, clients) -> dict:
    total = cluster.total_stats()
    return {
        "store_batches": sum(c.stats.store_batches for c in clients),
        "slices_store_coalesced": sum(c.stats.slices_store_coalesced
                                      for c in clients),
        "degraded_stores": total["degraded_stores"],
        "slices_written": total["slices_written"],
        "slices_created": sum(s["slices_created"]
                              for s in total["servers"].values()),
        "physical_bytes_written": wtf_io(cluster)["bytes_written"],
    }


def _drive_small_ops(cluster, scale, n_ops):
    """Each client: ONE transaction of ``n_ops`` small sequential pwrites —
    the record-at-a-time / manifest shape the write-behind buffer targets."""
    clients = [cluster.client() for _ in range(scale.n_clients)]
    lats: List[List[float]] = [[] for _ in range(scale.n_clients)]

    def work(i):
        c = clients[i]
        rng = np.random.RandomState(1000 + i)
        fd = c.open(f"/s{i}", "w")
        t0 = time.perf_counter()
        with c.transaction():
            off = 0
            for _ in range(n_ops):
                c.pwrite(fd, rng.bytes(SMALL_WRITE), off)
                off += SMALL_WRITE
        lats[i].append((time.perf_counter() - t0) / n_ops)
        c.close(fd)

    secs = _run_threads(work, scale.n_clients)
    return clients, secs, [x for l in lats for x in l]


def run_smallops(scale: Scale) -> dict:
    """Write-behind on vs. off over identical many-small-op transactions."""
    n_ops = SMALL_OPS.get(scale.name, 128)
    logical = n_ops * SMALL_WRITE * scale.n_clients
    row = {"n_ops": n_ops, "write_size": SMALL_WRITE}
    for key, wb in (("wtf", False), ("wtf_writeback", True)):
        with wtf_cluster(scale, write_behind=wb) as cluster:
            clients, secs, lats = _drive_small_ops(cluster, scale, n_ops)
            row[key] = {
                "throughput_mbs": logical / secs / 1e6,
                "writeback_flushes": sum(c.stats.writeback_flushes
                                         for c in clients),
                "slices_cross_op_coalesced": sum(
                    c.stats.slices_cross_op_coalesced for c in clients),
                **_row_stats(cluster, clients), **lat_summary(lats),
            }
    b, s = row["wtf_writeback"], row["wtf"]
    row["writeback_vs_eager"] = (b["throughput_mbs"]
                                 / max(s["throughput_mbs"], 1e-9))
    row["rounds_saved"] = s["store_batches"] - b["store_batches"]
    print(f"[write/smallops] {row['n_ops']}x{fmt_bytes(SMALL_WRITE)}/txn: "
          f"eager {s['throughput_mbs']:.0f} MB/s "
          f"({s['store_batches']} store rounds) | write-behind "
          f"{b['throughput_mbs']:.0f} MB/s ({b['store_batches']} rounds, "
          f"{b['writeback_flushes']} flushes, "
          f"{b['slices_cross_op_coalesced']} cross-op coalesced) | "
          f"{row['writeback_vs_eager']:.2f}x")
    assert b["store_batches"] < s["store_batches"], (
        "write-behind must issue strictly fewer store rounds than the "
        "same per-op pipeline over identical transactions")
    out = {"rows": [row], "scale": scale.name}
    save_result("write_bench_smallops", out)
    return out


def run(scale: Scale) -> dict:
    out = {"rows": [], "scale": scale.name}
    file_bytes = scale.total_bytes // scale.n_clients
    for ws in WRITE_SIZES:
        if ws > file_bytes:
            continue
        logical = max(1, file_bytes // ws) * ws * scale.n_clients
        row = {"write_size": ws}
        # scalar pipeline: store_batching off, one pwrite per chunk
        with wtf_cluster(scale) as cluster:
            cluster.store_batching = False
            clients, secs, lats = _drive_scalar(cluster, scale, ws,
                                                file_bytes)
            row["wtf"] = {"throughput_mbs": logical / secs / 1e6,
                          **_row_stats(cluster, clients), **lat_summary(lats)}
        # batched pipeline: identical chunks through the write scheduler
        with wtf_cluster(scale) as cluster:
            clients, secs, lats = _drive_batched(cluster, scale, ws,
                                                 file_bytes)
            row["wtf_batched"] = {"throughput_mbs": logical / secs / 1e6,
                                  **_row_stats(cluster, clients),
                                  **lat_summary(lats)}
        row["batched_vs_scalar"] = (row["wtf_batched"]["throughput_mbs"]
                                    / max(row["wtf"]["throughput_mbs"],
                                          1e-9))
        b, s = row["wtf_batched"], row["wtf"]
        row["rounds_saved"] = s["store_batches"] - b["store_batches"]
        out["rows"].append(row)
        print(f"[write] {fmt_bytes(ws)}: scalar "
              f"{s['throughput_mbs']:.0f} MB/s ({s['store_batches']} store "
              f"rounds) | batched {b['throughput_mbs']:.0f} MB/s "
              f"({b['store_batches']} rounds, "
              f"{b['slices_store_coalesced']} coalesced) | "
              f"{row['batched_vs_scalar']:.2f}x")
        assert b["store_batches"] < s["slices_written"], (
            "write scheduler must issue fewer store round-trips than the "
            "scalar pipeline writes slices")
    save_result("write_bench", out)
    return out


if __name__ == "__main__":
    _scale = Scale.of(sys.argv[1] if len(sys.argv) > 1 else "quick")
    _scenario = sys.argv[2] if len(sys.argv) > 2 else "vectored"
    if _scenario not in ("vectored", "smallops", "all"):
        raise ValueError(f"unknown scenario {_scenario!r}: "
                         "choose vectored, smallops, or all")
    if _scenario in ("vectored", "all"):
        run(_scale)
    if _scenario in ("smallops", "all"):
        run_smallops(_scale)
