"""Garbage-collection rate vs garbage fraction (Fig 15) + steady-state
overhead.  More garbage collects FASTER (sparse-file rewrite skips
garbage; live bytes are what costs I/O) — the paper's counterintuitive
result."""
from __future__ import annotations

import time

import numpy as np

from repro.core import GarbageCollector

from .common import Scale, fmt_bytes, save_result, wtf_cluster, wtf_io

FRACTIONS = [0.25, 0.5, 0.9]


def run(scale: Scale) -> dict:
    rows = []
    for frac in FRACTIONS:
        with wtf_cluster(scale) as cluster:
            fs = cluster.client()
            n_files = 32
            per = scale.total_bytes // n_files
            data = np.random.RandomState(0).bytes(per)
            for i in range(n_files):
                fd = fs.open(f"/g{i}", "w")
                fs.write(fd, data)
                fs.close(fd)
            # delete `frac` of the files → their slices become garbage
            victims = int(n_files * frac)
            for i in range(victims):
                fs.unlink(f"/g{i}")
            cluster.reset_io_stats()
            gc = GarbageCollector(cluster)
            gc.full_cycle()      # scan 1: marks garbage, collects nothing
            t0 = time.perf_counter()
            gc.full_cycle()      # scan 2: two-scan rule satisfied → collect
            secs = time.perf_counter() - t0
            reclaimed = sum(
                s.stats.gc_bytes_reclaimed
                for s in cluster.servers.values())
            rewritten = sum(
                s.stats.gc_bytes_rewritten
                for s in cluster.servers.values())
            rows.append({
                "garbage_fraction": frac,
                "reclaimed_bytes": reclaimed,
                "rewritten_bytes": rewritten,
                "rate_mbs": reclaimed / max(secs, 1e-9) / 1e6,
                "io_per_reclaimed": rewritten / max(reclaimed, 1),
                "wall_s": secs,
            })
            print(f"[gc] {int(frac * 100)}% garbage: reclaimed "
                  f"{fmt_bytes(reclaimed)} at "
                  f"{rows[-1]['rate_mbs']:.0f} MB/s, rewrite cost "
                  f"{rows[-1]['io_per_reclaimed']:.2f} B/B "
                  f"(paper: rate rises with garbage)")
    # the paper's key relation: rate increases with garbage fraction
    monotonic = all(rows[i]["rate_mbs"] <= rows[i + 1]["rate_mbs"] * 1.5
                    for i in range(len(rows) - 1))
    out = {"rows": rows, "rate_rises_with_garbage": monotonic,
           "scale": scale.name}
    save_result("gc_bench", out)
    return out


if __name__ == "__main__":
    run(Scale.of("quick"))
