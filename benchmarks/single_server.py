"""Single-server baseline vs the raw local filesystem (Fig 6): the local
FS bounds what any distributed FS on one node can do; the gap is the
system's overhead."""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from .common import (Scale, fmt_bytes, hdfs_cluster, save_result,
                     wtf_cluster, wtf_io)

CHUNK = 1 << 20


def _local_fs(total: int) -> dict:
    d = tempfile.mkdtemp(prefix="ext4_base_")
    path = os.path.join(d, "f")
    buf = b"l" * CHUNK
    t0 = time.perf_counter()
    with open(path, "wb", buffering=0) as f:
        for _ in range(total // CHUNK):
            f.write(buf)
    w = time.perf_counter() - t0
    t0 = time.perf_counter()
    with open(path, "rb", buffering=0) as f:
        while f.read(CHUNK):
            pass
    r = time.perf_counter() - t0
    os.unlink(path)
    return {"write_mbs": total / w / 1e6, "read_mbs": total / r / 1e6}


def run(scale: Scale) -> dict:
    total = scale.total_bytes
    one = Scale(**{**scale.__dict__, "n_servers": 1, "n_clients": 1})
    out = {"local": _local_fs(total)}

    with wtf_cluster(one) as cluster:
        fs = cluster.client()
        fd = fs.open("/f", "w")
        buf = b"w" * CHUNK
        t0 = time.perf_counter()
        for _ in range(total // CHUNK):
            fs.write(fd, buf)
        w = time.perf_counter() - t0
        fs.close(fd)
        fd = fs.open("/f", "r")
        t0 = time.perf_counter()
        off = 0
        while off < total:
            fs.pread(fd, CHUNK, off)
            off += CHUNK
        r = time.perf_counter() - t0
        out["wtf"] = {"write_mbs": total / w / 1e6,
                      "read_mbs": total / r / 1e6}

    with hdfs_cluster(one) as cluster:
        fs = cluster.client()
        wtr = fs.create("/f")
        t0 = time.perf_counter()
        for _ in range(total // CHUNK):
            wtr.write(buf)
            wtr.hflush()
        w = time.perf_counter() - t0
        wtr.close()
        rdr = fs.open("/f")
        t0 = time.perf_counter()
        off = 0
        while off < total:
            rdr.seek(off)
            rdr.read(CHUNK)
            off += CHUNK
        r = time.perf_counter() - t0
        out["hdfs"] = {"write_mbs": total / w / 1e6,
                       "read_mbs": total / r / 1e6}

    for k in ("local", "wtf", "hdfs"):
        print(f"[single_server] {k:6s}: write "
              f"{out[k]['write_mbs']:.0f} MB/s, read "
              f"{out[k]['read_mbs']:.0f} MB/s")
    out["wtf_frac_of_local_write"] = (out["wtf"]["write_mbs"]
                                      / out["local"]["write_mbs"])
    save_result("single_server", out)
    return out


if __name__ == "__main__":
    run(Scale.of("quick"))
