"""Sequential and random read throughput (Figs 11-12), WTF vs HDFS-like."""
from __future__ import annotations

import threading
import time
from typing import List

import numpy as np

from .common import (Scale, fmt_bytes, hdfs_cluster, lat_summary,
                     save_result, wtf_cluster, wtf_io)

READ_SIZES = [256 << 10, 1 << 20, 4 << 20]


def _drive(n_clients, file_bytes, read_size, mode, mk_reader):
    lats: List[List[float]] = [[] for _ in range(n_clients)]

    def work(i):
        read = mk_reader(i)
        rng = np.random.RandomState(i)
        n = file_bytes // read_size
        for j in range(n):
            off = (j * read_size if mode == "seq" else
                   int(rng.randint(0, max(1, file_bytes - read_size))))
            t0 = time.perf_counter()
            read(off, read_size)
            lats[i].append(time.perf_counter() - t0)

    threads = [threading.Thread(target=work, args=(i,))
               for i in range(n_clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return time.perf_counter() - t0, [x for l in lats for x in l]


def run(scale: Scale) -> dict:
    out = {"modes": {}, "scale": scale.name}
    file_bytes = scale.total_bytes // scale.n_clients
    for mode in ("seq", "random"):
        rows = []
        for rs in READ_SIZES:
            row = {"read_size": rs}
            with wtf_cluster(scale) as cluster:
                clients = [cluster.client()
                           for _ in range(scale.n_clients)]
                for i, c in enumerate(clients):
                    fd = c.open(f"/f{i}", "w")
                    c.write(fd, np.random.RandomState(i)
                            .bytes(file_bytes))
                    c.close(fd)
                cluster.reset_io_stats()
                fds = [c.open(f"/f{i}", "r")
                       for i, c in enumerate(clients)]

                def wtf_reader(i):
                    return lambda off, n: clients[i].pread(fds[i], n, off)

                secs, lats = _drive(scale.n_clients, file_bytes, rs, mode,
                                    wtf_reader)
                io = wtf_io(cluster)
                row["wtf"] = {
                    "throughput_mbs": io["bytes_read"] / secs / 1e6,
                    **lat_summary(lats)}
            with hdfs_cluster(scale) as cluster:
                fs = cluster.client()
                for i in range(scale.n_clients):
                    fs.write_all(f"/f{i}", np.random.RandomState(i)
                                 .bytes(file_bytes))
                base = cluster.io_stats()

                def hdfs_reader(i):
                    r = fs.open(f"/f{i}")

                    def read(off, n):
                        r.seek(off)
                        return r.read(n)
                    return read

                secs, lats = _drive(scale.n_clients, file_bytes, rs, mode,
                                    hdfs_reader)
                io = cluster.io_stats()
                row["hdfs"] = {
                    "throughput_mbs": (io["bytes_read"] - base["bytes_read"])
                    / secs / 1e6, **lat_summary(lats)}
            row["wtf_vs_hdfs"] = (row["wtf"]["throughput_mbs"]
                                  / max(row["hdfs"]["throughput_mbs"],
                                        1e-9))
            rows.append(row)
            print(f"[read/{mode}] {fmt_bytes(rs)}: WTF "
                  f"{row['wtf']['throughput_mbs']:.0f} MB/s | HDFS "
                  f"{row['hdfs']['throughput_mbs']:.0f} MB/s | ratio "
                  f"{row['wtf_vs_hdfs']:.2f} "
                  f"(paper: ≥0.8 seq, ≥1 random-small)")
        out["modes"][mode] = rows
    save_result("read_bench", out)
    return out


if __name__ == "__main__":
    run(Scale.of("quick"))
