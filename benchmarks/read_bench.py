"""Sequential and random read throughput (Figs 11-12), WTF vs HDFS-like,
plus the vectored-read mode: the same byte ranges issued through ``readv``
in batches, exercising the batched slice-fetch scheduler.

The scalar/vectored comparison reports the scheduler's effectiveness
counters from ``ClientStats``: ``fetch_batches`` (storage rounds actually
issued) and ``slices_coalesced`` (pointer fetches folded into an adjacent
round).  A vectored run must report fewer fetch batches than the scalar run
over identical ranges — that is the acceptance gauge of the I/O scheduler.

Usage: ``python -m benchmarks.read_bench [smoke|quick|full]``.
"""
from __future__ import annotations

import sys
import threading
import time
from typing import List

import numpy as np

from .common import (Scale, fmt_bytes, hdfs_cluster, lat_summary,
                     save_result, wtf_cluster, wtf_io)

READ_SIZES = [256 << 10, 1 << 20, 4 << 20]
VEC_BATCH = 16                       # ranges per readv call


def _offsets(mode: str, i: int, file_bytes: int, read_size: int) -> List[int]:
    rng = np.random.RandomState(i)
    n = file_bytes // read_size
    if mode == "seq":
        return [j * read_size for j in range(n)]
    return [int(rng.randint(0, max(1, file_bytes - read_size)))
            for _ in range(n)]


def _drive(n_clients, file_bytes, read_size, mode, mk_reader):
    lats: List[List[float]] = [[] for _ in range(n_clients)]

    def work(i):
        read = mk_reader(i)
        for off in _offsets(mode, i, file_bytes, read_size):
            t0 = time.perf_counter()
            read(off, read_size)
            lats[i].append(time.perf_counter() - t0)

    threads = [threading.Thread(target=work, args=(i,))
               for i in range(n_clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return time.perf_counter() - t0, [x for l in lats for x in l]


def _drive_vectored(n_clients, file_bytes, read_size, mode, mk_readv):
    """Same ranges as ``_drive``, issued as readv batches of VEC_BATCH."""
    lats: List[List[float]] = [[] for _ in range(n_clients)]

    def work(i):
        readv = mk_readv(i)
        offs = _offsets(mode, i, file_bytes, read_size)
        for j in range(0, len(offs), VEC_BATCH):
            ranges = [(o, read_size) for o in offs[j:j + VEC_BATCH]]
            t0 = time.perf_counter()
            readv(ranges)
            # amortized per-read latency, so wtf/wtf_vec percentiles in
            # the saved results compare like for like
            lats[i].append((time.perf_counter() - t0) / len(ranges))

    threads = [threading.Thread(target=work, args=(i,))
               for i in range(n_clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return time.perf_counter() - t0, [x for l in lats for x in l]


def _sched_stats(clients) -> dict:
    return {
        "fetch_batches": sum(c.stats.fetch_batches for c in clients),
        "slices_coalesced": sum(c.stats.slices_coalesced for c in clients),
    }


def run(scale: Scale) -> dict:
    out = {"modes": {}, "scale": scale.name}
    file_bytes = scale.total_bytes // scale.n_clients
    for mode in ("seq", "random"):
        rows = []
        for rs in READ_SIZES:
            if rs > file_bytes:
                continue
            row = {"read_size": rs}
            with wtf_cluster(scale) as cluster:
                clients = [cluster.client()
                           for _ in range(scale.n_clients)]
                for i, c in enumerate(clients):
                    fd = c.open(f"/f{i}", "w")
                    c.write(fd, np.random.RandomState(i)
                            .bytes(file_bytes))
                    c.close(fd)
                cluster.reset_io_stats()
                fds = [c.open(f"/f{i}", "r")
                       for i, c in enumerate(clients)]

                # ---- scalar preads (one storage round per extent run)
                def wtf_reader(i):
                    return lambda off, n: clients[i].pread(fds[i], n, off)

                # identical logical volume for both rows: physical
                # bytes_read diverges under coalescing (overlaps dedup'd,
                # gap bytes fetched-and-discarded), so throughput must be
                # logical-bytes / wall-clock to stay comparable
                logical = (file_bytes // rs) * rs * scale.n_clients

                base = _sched_stats(clients)
                secs, lats = _drive(scale.n_clients, file_bytes, rs, mode,
                                    wtf_reader)
                io = wtf_io(cluster)
                scalar_sched = {
                    k: v - base[k] for k, v in _sched_stats(clients).items()}
                row["wtf"] = {
                    "throughput_mbs": logical / secs / 1e6,
                    "physical_bytes_read": io["bytes_read"],
                    **scalar_sched, **lat_summary(lats)}

                # ---- vectored readv over the same ranges
                cluster.reset_io_stats()
                base = _sched_stats(clients)

                def wtf_readv(i):
                    return lambda ranges: clients[i].readv(fds[i], ranges)

                secs, lats = _drive_vectored(scale.n_clients, file_bytes,
                                             rs, mode, wtf_readv)
                io = wtf_io(cluster)
                vec_sched = {
                    k: v - base[k] for k, v in _sched_stats(clients).items()}
                row["wtf_vec"] = {
                    "throughput_mbs": logical / secs / 1e6,
                    "physical_bytes_read": io["bytes_read"],
                    **vec_sched, **lat_summary(lats)}
            with hdfs_cluster(scale) as cluster:
                fs = cluster.client()
                for i in range(scale.n_clients):
                    fs.write_all(f"/f{i}", np.random.RandomState(i)
                                 .bytes(file_bytes))
                base = cluster.io_stats()

                def hdfs_reader(i):
                    r = fs.open(f"/f{i}")

                    def read(off, n):
                        r.seek(off)
                        return r.read(n)
                    return read

                secs, lats = _drive(scale.n_clients, file_bytes, rs, mode,
                                    hdfs_reader)
                io = cluster.io_stats()
                row["hdfs"] = {
                    "throughput_mbs": (io["bytes_read"] - base["bytes_read"])
                    / secs / 1e6, **lat_summary(lats)}
            row["wtf_vs_hdfs"] = (row["wtf"]["throughput_mbs"]
                                  / max(row["hdfs"]["throughput_mbs"],
                                        1e-9))
            row["vec_vs_scalar"] = (row["wtf_vec"]["throughput_mbs"]
                                    / max(row["wtf"]["throughput_mbs"],
                                          1e-9))
            rows.append(row)
            print(f"[read/{mode}] {fmt_bytes(rs)}: WTF "
                  f"{row['wtf']['throughput_mbs']:.0f} MB/s | HDFS "
                  f"{row['hdfs']['throughput_mbs']:.0f} MB/s | ratio "
                  f"{row['wtf_vs_hdfs']:.2f} "
                  f"(paper: ≥0.8 seq, ≥1 random-small)")
            print(f"[read/{mode}] {fmt_bytes(rs)}: vectored "
                  f"{row['wtf_vec']['throughput_mbs']:.0f} MB/s "
                  f"({row['vec_vs_scalar']:.2f}x scalar) | fetch batches "
                  f"{row['wtf_vec']['fetch_batches']} vs "
                  f"{row['wtf']['fetch_batches']} scalar | coalesced "
                  f"{row['wtf_vec']['slices_coalesced']} slice fetches")
        out["modes"][mode] = rows
    save_result("read_bench", out)
    return out


if __name__ == "__main__":
    run(Scale.of(sys.argv[1] if len(sys.argv) > 1 else "quick"))
