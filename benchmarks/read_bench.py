"""Sequential and random read throughput (Figs 11-12), WTF vs HDFS-like,
plus the vectored-read mode: the same byte ranges issued through ``readv``
in batches, exercising the batched slice-fetch scheduler.

Fairness rules (all rows, all systems):

* **Throughput is logical bytes / wall-clock.**  Physical ``bytes_read``
  diverges per system — WTF coalescing fetches-and-discards gap bytes,
  HDFS-like re-reads whole blocks, readahead speculates — so physical
  traffic is reported as a diagnostic, never used as the numerator.
* **Same total bytes per mode.**  Scalar and vectored runs issue the
  identical offset list; the vectored run batches it into readv calls.
* **Honest latency samples.**  Vectored latencies are per *call* (what a
  caller actually waits for), never amortized per range, and the batch
  size shrinks at small scales so both modes have a comparable number of
  timed iterations (``n`` in the saved summaries is the real sample
  count for that mode).
* **Cold cluster per pass.**  Scalar and vectored each get a fresh
  cluster (and fresh clients): neither pass's block cache or server
  readahead pool may subsidize — or pollute — the other's.  (A shared
  cluster is subtly unfair BOTH ways: the first pass's pooled windows
  are sized for its own round size, so the second pass inherits a
  stream detector parked at EOF and a pool full of windows it cannot
  hit.)

The scalar/vectored comparison still reports the scheduler's counters
from ``ClientStats`` (``fetch_batches``, ``slices_coalesced``) plus the
new data-plane counters: server ``readahead_hits``/``readahead_bytes``
and client ``block_cache_hits``/``block_cache_misses``.

Two correctness sections ride along and hard-assert:

* ``hot_reread`` — a cached re-read must complete with ZERO additional
  storage retrieval rounds (block cache serves every extent);
* ``config_isolation`` — readahead x block-cache on/off (4 configs) must
  produce byte-identical read streams (same sha256 digest).

Usage: ``python -m benchmarks.read_bench [smoke|quick|full]``.
"""
from __future__ import annotations

import hashlib
import sys
import threading
import time
from typing import Dict, List

import numpy as np

from repro.core.blockcache import DEFAULT_BLOCK_CACHE_BYTES

from .common import (Scale, fmt_bytes, hdfs_cluster, lat_summary,
                     save_result, wtf_cluster, wtf_io)

READ_SIZES = [256 << 10, 1 << 20, 4 << 20]
VEC_BATCH = 16                       # max ranges per readv call
MIN_VEC_CALLS = 2                    # shrink batches below this per client


def _offsets(mode: str, i: int, file_bytes: int, read_size: int) -> List[int]:
    rng = np.random.RandomState(i)
    n = file_bytes // read_size
    if mode == "seq":
        return [j * read_size for j in range(n)]
    return [int(rng.randint(0, max(1, file_bytes - read_size)))
            for _ in range(n)]


def _drive(n_clients, file_bytes, read_size, mode, mk_reader):
    lats: List[List[float]] = [[] for _ in range(n_clients)]

    def work(i):
        read = mk_reader(i)
        for off in _offsets(mode, i, file_bytes, read_size):
            t0 = time.perf_counter()
            read(off, read_size)
            lats[i].append(time.perf_counter() - t0)

    threads = [threading.Thread(target=work, args=(i,))
               for i in range(n_clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return time.perf_counter() - t0, [x for l in lats for x in l]


def _drive_vectored(n_clients, file_bytes, read_size, mode, mk_readv):
    """Same ranges as ``_drive``, issued as readv batches.

    Latencies are whole-call (a readv caller waits for the whole batch);
    the batch size shrinks at small scales so the per-mode sample count
    stays comparable to the scalar run instead of collapsing to one or
    two giant calls.
    """
    lats: List[List[float]] = [[] for _ in range(n_clients)]
    n_reads = file_bytes // read_size
    batch = max(2, min(VEC_BATCH, n_reads // MIN_VEC_CALLS or 1))

    def work(i):
        readv = mk_readv(i)
        offs = _offsets(mode, i, file_bytes, read_size)
        for j in range(0, len(offs), batch):
            ranges = [(o, read_size) for o in offs[j:j + batch]]
            t0 = time.perf_counter()
            readv(ranges)
            lats[i].append(time.perf_counter() - t0)

    threads = [threading.Thread(target=work, args=(i,))
               for i in range(n_clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return (time.perf_counter() - t0, [x for l in lats for x in l], batch)


def _sched_stats(clients) -> dict:
    return {
        "fetch_batches": sum(c.stats.fetch_batches for c in clients),
        "slices_coalesced": sum(c.stats.slices_coalesced for c in clients),
        "block_cache_hits": sum(c.stats.block_cache_hits for c in clients),
        "block_cache_misses": sum(c.stats.block_cache_misses
                                  for c in clients),
    }


def _srv_totals(cluster) -> Dict[str, float]:
    out: Dict[str, float] = {}
    for s in cluster.total_stats()["servers"].values():
        for k, v in s.items():
            out[k] = out.get(k, 0) + v
    return out


def _wtf_trial(scale: Scale, rs: int, mode: str, vectored: bool) -> dict:
    """One cold measured WTF pass on its OWN fresh cluster (see the
    fairness rules in the module docstring): separate writer clients
    load the files, then fresh clients — cold plan and block caches —
    do the timed reads."""
    file_bytes = scale.total_bytes // scale.n_clients
    with wtf_cluster(scale) as cluster:
        for i in range(scale.n_clients):
            w = cluster.client()
            fd = w.open(f"/f{i}", "w")
            w.write(fd, np.random.RandomState(i).bytes(file_bytes))
            w.close(fd)
        cluster.reset_io_stats()
        clients = [cluster.client() for _ in range(scale.n_clients)]
        fds = [c.open(f"/f{i}", "r") for i, c in enumerate(clients)]
        base = _sched_stats(clients)
        batch = None
        if vectored:
            def mk_readv(i):
                return lambda ranges: clients[i].readv(fds[i], ranges)
            secs, lats, batch = _drive_vectored(
                scale.n_clients, file_bytes, rs, mode, mk_readv)
        else:
            def mk_reader(i):
                return lambda off, n: clients[i].pread(fds[i], n, off)
            secs, lats = _drive(scale.n_clients, file_bytes, rs, mode,
                                mk_reader)
        io = wtf_io(cluster)
        srv = _srv_totals(cluster)
        sched = {k: v - base[k] for k, v in _sched_stats(clients).items()}
        out = {"secs": secs, "lats": lats,
               "physical_bytes_read": io["bytes_read"],
               "readahead_hits": int(srv["readahead_hits"]),
               "readahead_bytes": int(srv["readahead_bytes"]),
               **sched}
        if batch is not None:
            out["ranges_per_call"] = batch
        return out


def _wtf_pass(scale: Scale, rs: int, mode: str, vectored: bool,
              trials: int) -> dict:
    """Best-of-``trials`` cold passes: single cold passes at small
    scales finish in milliseconds, where scheduler noise alone flips
    scalar/vectored comparisons either way.  Throughput uses the
    *fastest* trial's wall-clock (timeit-style — the least-interfered
    sample; means and medians of ms-scale multi-thread passes absorb
    whatever else the machine was doing); latency percentiles pool
    every trial's per-call samples (``n`` stays the honest total)."""
    runs = [_wtf_trial(scale, rs, mode, vectored) for _ in range(trials)]
    best = min(runs, key=lambda r: r["secs"])
    file_bytes = scale.total_bytes // scale.n_clients
    logical = (file_bytes // rs) * rs * scale.n_clients
    lats = [x for r in runs for x in r["lats"]]
    out = {k: v for k, v in best.items() if k not in ("secs", "lats")}
    out.update({"throughput_mbs": logical / best["secs"] / 1e6,
                "best_pass_s": best["secs"], "trials": trials,
                **lat_summary(lats)})
    return out


# -------------------------------------------------- correctness sections
def hot_reread(scale: Scale) -> dict:
    """A block-cached re-read must cost zero storage retrieval rounds."""
    n = min(1 << 20, scale.total_bytes)
    with wtf_cluster(scale) as cluster:
        fs = cluster.client()
        fd = fs.open("/hot", "w")
        fs.write(fd, np.random.RandomState(7).bytes(n))
        fs.close(fd)
        fd = fs.open("/hot", "r")
        cold = fs.pread(fd, n, 0)            # fills the block cache
        before = _srv_totals(cluster)["read_rounds"]
        t0 = time.perf_counter()
        hot = fs.pread(fd, n, 0)
        secs = time.perf_counter() - t0
        delta = _srv_totals(cluster)["read_rounds"] - before
        assert hot == cold, "hot re-read returned different bytes"
        assert delta == 0, (
            f"hot re-read cost {delta} storage rounds (want 0)")
        return {"bytes": n, "rounds_delta": int(delta),
                "block_cache_hits": fs.stats.block_cache_hits,
                "hot_read_s": secs}


def config_isolation(scale: Scale) -> dict:
    """readahead x block-cache on/off must be byte-identical (sha256
    digest over cold sequential + hot sequential + random readv)."""
    n = min(2 << 20, scale.total_bytes)
    sz = 128 << 10
    payload = np.random.RandomState(11).bytes(n)
    digests: Dict[str, str] = {}
    for ra in (True, False):
        for cache_bytes in (DEFAULT_BLOCK_CACHE_BYTES, 0):
            with wtf_cluster(scale, readahead=ra,
                             block_cache_bytes=cache_bytes) as cluster:
                fs = cluster.client()
                fd = fs.open("/iso", "w")
                fs.write(fd, payload)
                fs.close(fd)
                fd = fs.open("/iso", "r")
                h = hashlib.sha256()
                for off in range(0, n, sz):          # cold sequential
                    h.update(fs.pread(fd, sz, off))
                for off in range(0, n, sz):          # hot (cache-served)
                    h.update(fs.pread(fd, sz, off))
                rng = np.random.RandomState(3)
                ranges = [(int(rng.randint(0, max(1, n - sz))), sz)
                          for _ in range(16)]
                for chunk in fs.readv(fd, ranges):   # vectored random
                    h.update(chunk)
                digests[f"readahead={ra},cache={cache_bytes > 0}"] = \
                    h.hexdigest()
    assert len(set(digests.values())) == 1, (
        f"config digest divergence: {digests}")
    return {"identical": True, "digest": next(iter(digests.values())),
            "configs": digests}


#: Best-of-N trials per (mode, size, variant) pass; 1 at full scale
#: where a single pass is long enough to be stable on its own.
TRIALS = {"smoke": 5, "quick": 3, "full": 1}


def run(scale: Scale) -> dict:
    out = {"modes": {}, "mode_summary": {}, "scale": scale.name}
    trials = TRIALS.get(scale.name, 1)
    file_bytes = scale.total_bytes // scale.n_clients
    for mode in ("seq", "random"):
        rows = []
        for rs in READ_SIZES:
            if rs > file_bytes:
                continue
            row = {"read_size": rs}
            # identical logical volume for every row of this size:
            # throughput is logical-bytes / wall-clock for ALL systems
            # (physical bytes_read diverges under coalescing, readahead
            # speculation, and HDFS block re-reads — reported only as a
            # diagnostic)
            logical = (file_bytes // rs) * rs * scale.n_clients
            row["wtf"] = _wtf_pass(scale, rs, mode, vectored=False,
                                   trials=trials)
            row["wtf_vec"] = _wtf_pass(scale, rs, mode, vectored=True,
                                       trials=trials)
            with hdfs_cluster(scale) as cluster:
                fs = cluster.client()
                for i in range(scale.n_clients):
                    fs.write_all(f"/f{i}", np.random.RandomState(i)
                                 .bytes(file_bytes))
                base = cluster.io_stats()

                def hdfs_reader(i):
                    r = fs.open(f"/f{i}")

                    def read(off, n):
                        r.seek(off)
                        return r.read(n)
                    return read

                secs, lats = _drive(scale.n_clients, file_bytes, rs, mode,
                                    hdfs_reader)
                io = cluster.io_stats()
                row["hdfs"] = {
                    "throughput_mbs": logical / secs / 1e6,
                    "physical_bytes_read": (io["bytes_read"]
                                            - base["bytes_read"]),
                    **lat_summary(lats)}
            row["wtf_vs_hdfs"] = (row["wtf"]["throughput_mbs"]
                                  / max(row["hdfs"]["throughput_mbs"],
                                        1e-9))
            row["vec_vs_scalar"] = (row["wtf_vec"]["throughput_mbs"]
                                    / max(row["wtf"]["throughput_mbs"],
                                          1e-9))
            rows.append(row)
            print(f"[read/{mode}] {fmt_bytes(rs)}: WTF "
                  f"{row['wtf']['throughput_mbs']:.0f} MB/s | HDFS "
                  f"{row['hdfs']['throughput_mbs']:.0f} MB/s | ratio "
                  f"{row['wtf_vs_hdfs']:.2f} "
                  f"(paper: ≥0.8 seq, ≥1 random-small)")
            print(f"[read/{mode}] {fmt_bytes(rs)}: vectored "
                  f"{row['wtf_vec']['throughput_mbs']:.0f} MB/s "
                  f"({row['vec_vs_scalar']:.2f}x scalar, "
                  f"{row['wtf_vec']['ranges_per_call']} ranges/call) | "
                  f"fetch batches {row['wtf_vec']['fetch_batches']} vs "
                  f"{row['wtf']['fetch_batches']} scalar | readahead hits "
                  f"{row['wtf']['readahead_hits']} scalar / "
                  f"{row['wtf_vec']['readahead_hits']} vec")
        out["modes"][mode] = rows
        # Per-mode aggregate: total logical bytes over total best-pass
        # time — the stable scalar-vs-vectored comparison (per-row ratios
        # at small scales ride on few-ms denominators).
        logical_total = sum((file_bytes // r["read_size"])
                            * r["read_size"] * scale.n_clients
                            for r in rows)
        agg = {}
        for variant in ("wtf", "wtf_vec"):
            secs = sum(r[variant]["best_pass_s"] for r in rows)
            agg[variant] = {
                "throughput_mbs": logical_total / secs / 1e6,
                "readahead_hits": sum(r[variant]["readahead_hits"]
                                      for r in rows)}
        agg["vec_vs_scalar"] = (agg["wtf_vec"]["throughput_mbs"]
                                / max(agg["wtf"]["throughput_mbs"], 1e-9))
        out["mode_summary"][mode] = agg
        print(f"[read/{mode}] aggregate: vectored "
              f"{agg['vec_vs_scalar']:.2f}x scalar "
              f"({agg['wtf_vec']['throughput_mbs']:.0f} vs "
              f"{agg['wtf']['throughput_mbs']:.0f} MB/s), "
              f"{agg['wtf']['readahead_hits']} scalar readahead hits")
    out["hot_reread"] = hot_reread(scale)
    print(f"[read/hot] {fmt_bytes(out['hot_reread']['bytes'])} re-read: "
          f"{out['hot_reread']['rounds_delta']} storage rounds "
          f"({out['hot_reread']['block_cache_hits']} block-cache hits)")
    out["config_isolation"] = config_isolation(scale)
    print(f"[read/iso] 4 readahead x block-cache configs byte-identical "
          f"(sha256 {out['config_isolation']['digest'][:12]}…)")
    save_result("read_bench", out)
    return out


if __name__ == "__main__":
    run(Scale.of(sys.argv[1] if len(sys.argv) > 1 else "quick"))
