"""Map-reduce sort — the paper's end-to-end benchmark (Table 2, Figs 4-5).

Record-oriented input (10-byte uniform keys + payload).  Three stages:
  bucketing  → partition records into key-range buckets
  sorting    → sort each bucket
  merging    → concatenate sorted buckets

Conventional (HDFS-like) execution reads AND rewrites the data at every
stage: 3R + 3W.  WTF file slicing reads keys (bucketing) and bucket
contents (sorting) but *writes only metadata* — yank/paste rearrangement
and a final concat: 2R + 0W.  Table 2 exactly.
"""
from __future__ import annotations

import struct
import time
from typing import List

import numpy as np

from repro.data.records import RecordFile, RecordWriter

from .common import (Scale, Timer, fmt_bytes, hdfs_cluster, save_result,
                     wtf_cluster, wtf_io)


def _gen_records(n: int, record_bytes: int, seed: int = 0) -> List[bytes]:
    rng = np.random.RandomState(seed)
    out = []
    payload = b"x" * (record_bytes - 10)
    for i in range(n):
        key = rng.bytes(10)
        out.append(key + payload)
    return out


def _key(rec: bytes) -> bytes:
    return rec[:10]


def _bucket_of(key: bytes, n_buckets: int) -> int:
    return min(n_buckets - 1, int.from_bytes(key[:4], "big")
               * n_buckets >> 32)


# ------------------------------------------------------------------- WTF
def wtf_sort(scale: Scale, n_buckets: int = 8) -> dict:
    n_rec = scale.total_bytes // scale.record_bytes
    records = _gen_records(n_rec, scale.record_bytes)
    timer = Timer()
    with wtf_cluster(scale) as cluster:
        fs = cluster.client()
        w = RecordWriter(fs, "/input", scale.record_bytes)
        for r in records:
            w.append(r)
        w.close()
        cluster.reset_io_stats()              # accounting starts post-load

        # ---- stage 1: bucketing — read keys, yank record slices into
        # bucket files; zero data writes
        with timer.lap("bucketing"):
            rdr = RecordFile(fs, "/input", scale.record_bytes)
            keys = [(_key(rdr.read_record(i)), i) for i in range(n_rec)]
            buckets: List[List[int]] = [[] for _ in range(n_buckets)]
            for k, i in keys:
                buckets[_bucket_of(k, n_buckets)].append(i)
            for b, idxs in enumerate(buckets):
                fd = fs.open(f"/bucket_{b:03d}", "w")
                for i in idxs:
                    fs.paste(fd, rdr.yank_records(i, 1))
                fs.close(fd)

        # ---- stage 2: sorting — per bucket, read keys, paste a permuted
        # slice order; zero data writes
        with timer.lap("sorting"):
            for b in range(n_buckets):
                br = RecordFile(fs, f"/bucket_{b:03d}",
                                scale.record_bytes)
                n_b = br.count
                bkeys = [( _key(br.read_record(i)), i) for i in range(n_b)]
                bkeys.sort()
                fd = fs.open(f"/sorted_{b:03d}", "w")
                for _, i in bkeys:
                    fs.paste(fd, br.yank_records(i, 1))
                fs.close(fd)

        # ---- stage 3: merging — pure metadata concat
        with timer.lap("merging"):
            fs.concat([f"/sorted_{b:03d}" for b in range(n_buckets)],
                      "/output")

        io = wtf_io(cluster)
        # verify order
        out = RecordFile(fs, "/output", scale.record_bytes)
        prev = b""
        for i in range(n_rec):
            k = _key(out.read_record(i))
            assert k >= prev, "output not sorted"
            prev = k
    return {"system": "wtf", "stages_s": dict(timer.laps),
            "total_s": timer.total, **io}


# ------------------------------------------------- WTF, key-only (beyond)
def wtf_sort_keyonly(scale: Scale, n_buckets: int = 8) -> dict:
    """Beyond-paper: bucketing and sorting only ever need the 10-byte
    keys — `pread` the keys, `yank`/`paste` the records.  Data reads drop
    from the paper's 2×R to ~2·n·10 bytes (≈0.03% of the dataset)."""
    n_rec = scale.total_bytes // scale.record_bytes
    rb = scale.record_bytes
    records = _gen_records(n_rec, rb)
    timer = Timer()
    with wtf_cluster(scale) as cluster:
        fs = cluster.client()
        w = RecordWriter(fs, "/input", rb)
        for r in records:
            w.append(r)
        w.close()
        cluster.reset_io_stats()

        with timer.lap("bucketing"):
            rdr = RecordFile(fs, "/input", rb)
            fd = fs.open("/input", "r")
            keys = [(fs.pread(fd, 10, i * rb), i) for i in range(n_rec)]
            buckets: List[List[tuple]] = [[] for _ in range(n_buckets)]
            for k, i in keys:
                buckets[_bucket_of(k, n_buckets)].append((k, i))

        # bucket files never materialize: sort key lists directly and
        # paste straight into the output — the "buckets" are metadata
        with timer.lap("sorting"):
            for b in range(n_buckets):
                buckets[b].sort()

        with timer.lap("merging"):
            out = fs.open("/output", "w")
            for b in range(n_buckets):
                for _, i in buckets[b]:
                    fs.paste(out, rdr.yank_records(i, 1))
            fs.close(out)

        io = wtf_io(cluster)
        outf = RecordFile(fs, "/output", rb)
        prev = b""
        for i in range(0, n_rec, max(1, n_rec // 64)):
            k = _key(outf.read_record(i))
            assert k >= prev, "output not sorted"
            prev = k
    return {"system": "wtf-keyonly", "stages_s": dict(timer.laps),
            "total_s": timer.total, **io}


# ----------------------------------------------------------------- HDFS
def hdfs_sort(scale: Scale, n_buckets: int = 8) -> dict:
    n_rec = scale.total_bytes // scale.record_bytes
    rb = scale.record_bytes
    records = _gen_records(n_rec, rb)
    timer = Timer()
    with hdfs_cluster(scale) as cluster:
        fs = cluster.client()
        w = fs.create("/input")
        for r in records:
            w.write(r)
        w.close()
        base = cluster.io_stats()

        with timer.lap("bucketing"):
            data = fs.read_all("/input")
            buckets: List[List[bytes]] = [[] for _ in range(n_buckets)]
            for i in range(n_rec):
                rec = data[i * rb:(i + 1) * rb]
                buckets[_bucket_of(_key(rec), n_buckets)].append(rec)
            for b, recs in enumerate(buckets):
                fs.write_all(f"/bucket_{b:03d}", b"".join(recs))

        with timer.lap("sorting"):
            for b in range(n_buckets):
                data = fs.read_all(f"/bucket_{b:03d}")
                recs = [data[i:i + rb] for i in range(0, len(data), rb)]
                recs.sort(key=_key)
                fs.write_all(f"/sorted_{b:03d}", b"".join(recs))

        with timer.lap("merging"):
            fs.concat([f"/sorted_{b:03d}" for b in range(n_buckets)],
                      "/output")

        io = cluster.io_stats()
        io = {k: io[k] - base[k] for k in io}
        out = fs.read_all("/output")
        prev = b""
        for i in range(n_rec):
            k = _key(out[i * rb:(i + 1) * rb])
            assert k >= prev, "output not sorted"
            prev = k
    return {"system": "hdfs-like", "stages_s": dict(timer.laps),
            "total_s": timer.total, **io}


def run(scale: Scale) -> dict:
    w = wtf_sort(scale)
    ko = wtf_sort_keyonly(scale)
    h = hdfs_sort(scale)
    total = scale.total_bytes
    result = {
        "scale": scale.name, "dataset_bytes": total,
        "wtf": w, "hdfs": h, "wtf_keyonly": ko,
        # Table 2 accounting, normalized to dataset size
        "wtf_read_x": w["bytes_read"] / total,
        "wtf_write_x": w["bytes_written"] / total,
        "hdfs_read_x": h["bytes_read"] / total,
        "hdfs_write_x": h["bytes_written"] / total,
        "keyonly_read_x": ko["bytes_read"] / total,
        "speedup": h["total_s"] / max(w["total_s"], 1e-9),
        "keyonly_speedup": h["total_s"] / max(ko["total_s"], 1e-9),
    }
    save_result("sort_mapreduce", result)
    print(f"[sort] dataset={fmt_bytes(total)}")
    print(f"[sort] WTF : R={result['wtf_read_x']:.2f}x "
          f"W={result['wtf_write_x']:.2f}x  t={w['total_s']:.2f}s "
          f"stages={ {k: round(v, 2) for k, v in w['stages_s'].items()} }")
    print(f"[sort] WTF-keyonly (beyond paper): "
          f"R={result['keyonly_read_x']:.4f}x W=0.00x "
          f"t={ko['total_s']:.2f}s")
    print(f"[sort] HDFS: R={result['hdfs_read_x']:.2f}x "
          f"W={result['hdfs_write_x']:.2f}x  t={h['total_s']:.2f}s "
          f"stages={ {k: round(v, 2) for k, v in h['stages_s'].items()} }")
    print(f"[sort] speedup: {result['speedup']:.2f}x paper-faithful, "
          f"{result['keyonly_speedup']:.2f}x key-only "
          f"(paper: 4x on 100 GB/15 nodes)")
    return result


if __name__ == "__main__":
    run(Scale.of("quick"))
