"""Map-reduce sort — the paper's end-to-end benchmark (Table 2, Figs 4-5).

Record-oriented input (10-byte uniform keys + payload).  Three stages:
  bucketing  → partition records into key-range buckets
  sorting    → sort each bucket
  merging    → concatenate sorted buckets

Conventional (HDFS-like) execution reads AND rewrites the data at every
stage: 3R + 3W.  WTF file slicing reads keys (bucketing) and bucket
contents (sorting) but *writes only metadata* — yank/paste rearrangement
and a final concat: 2R + 0W.  Table 2 exactly.
"""
from __future__ import annotations

import struct
import time
from typing import List

import numpy as np

from repro.data.records import RecordFile, RecordWriter

from .common import (Scale, Timer, fmt_bytes, hdfs_cluster, save_result,
                     wtf_cluster, wtf_io)


def _gen_records(n: int, record_bytes: int, seed: int = 0) -> List[bytes]:
    rng = np.random.RandomState(seed)
    out = []
    payload = b"x" * (record_bytes - 10)
    for i in range(n):
        key = rng.bytes(10)
        out.append(key + payload)
    return out


def _key(rec) -> bytes:
    # Vectored reads return zero-copy buffers; sort keys must be bytes
    # (memoryview has no ordering).
    return bytes(rec[:10])


def _bucket_of(key: bytes, n_buckets: int) -> int:
    return min(n_buckets - 1, int.from_bytes(key[:4], "big")
               * n_buckets >> 32)


RECORD_BATCH = 64        # records per vectored read / yank batch


# ------------------------------------------------------------------- WTF
def wtf_sort(scale: Scale, n_buckets: int = 8) -> dict:
    n_rec = scale.total_bytes // scale.record_bytes
    records = _gen_records(n_rec, scale.record_bytes)
    timer = Timer()
    with wtf_cluster(scale) as cluster:
        fs = cluster.client()
        w = RecordWriter(fs, "/input", scale.record_bytes)
        for lo in range(0, n_rec, RECORD_BATCH):
            w.append_many(records[lo:lo + RECORD_BATCH])
        w.close()
        cluster.reset_io_stats()              # accounting starts post-load

        # ---- stage 1: bucketing — read records (vectored, batches of
        # RECORD_BATCH), yank record slices into bucket files with one
        # yankv + pastev per bucket; zero data writes
        with timer.lap("bucketing"):
            rdr = RecordFile(fs, "/input", scale.record_bytes)
            keys = []
            for lo in range(0, n_rec, RECORD_BATCH):
                idxs = list(range(lo, min(lo + RECORD_BATCH, n_rec)))
                for i, rec in zip(idxs, rdr.read_records_batch(idxs)):
                    keys.append((_key(rec), i))
            buckets: List[List[int]] = [[] for _ in range(n_buckets)]
            for k, i in keys:
                buckets[_bucket_of(k, n_buckets)].append(i)
            for b, idxs in enumerate(buckets):
                yanked = rdr.yank_record_runs([(i, 1) for i in idxs])
                with fs.open_file(f"/bucket_{b:03d}", "w") as f:
                    f.pastev(yanked)

        # ---- stage 2: sorting — per bucket, read records (vectored),
        # paste the permuted slice order in one op; zero data writes
        with timer.lap("sorting"):
            for b in range(n_buckets):
                br = RecordFile(fs, f"/bucket_{b:03d}",
                                scale.record_bytes)
                bkeys = []
                for lo in range(0, br.count, RECORD_BATCH):
                    idxs = list(range(lo, min(lo + RECORD_BATCH, br.count)))
                    for i, rec in zip(idxs, br.read_records_batch(idxs)):
                        bkeys.append((_key(rec), i))
                bkeys.sort()
                yanked = br.yank_record_runs([(i, 1) for _, i in bkeys])
                with fs.open_file(f"/sorted_{b:03d}", "w") as f:
                    f.pastev(yanked)

        # ---- stage 3: merging — pure metadata concat
        with timer.lap("merging"):
            fs.concat([f"/sorted_{b:03d}" for b in range(n_buckets)],
                      "/output")

        io = wtf_io(cluster)
        # verify order
        out = RecordFile(fs, "/output", scale.record_bytes)
        prev = b""
        for i in range(n_rec):
            k = _key(out.read_record(i))
            assert k >= prev, "output not sorted"
            prev = k
    return {"system": "wtf", "stages_s": dict(timer.laps),
            "total_s": timer.total, **io}


# ------------------------------------------------- WTF, key-only (beyond)
def wtf_sort_keyonly(scale: Scale, n_buckets: int = 8) -> dict:
    """Beyond-paper: bucketing and sorting only ever need the 10-byte
    keys — `pread` the keys, `yank`/`paste` the records.  Data reads drop
    from the paper's 2×R to ~2·n·10 bytes (≈0.03% of the dataset)."""
    n_rec = scale.total_bytes // scale.record_bytes
    rb = scale.record_bytes
    records = _gen_records(n_rec, rb)
    timer = Timer()
    with wtf_cluster(scale) as cluster:
        fs = cluster.client()
        w = RecordWriter(fs, "/input", rb)
        for lo in range(0, n_rec, RECORD_BATCH):
            w.append_many(records[lo:lo + RECORD_BATCH])
        w.close()
        cluster.reset_io_stats()

        with timer.lap("bucketing"):
            rdr = RecordFile(fs, "/input", rb)
            # Vectored key reads: RECORD_BATCH 10-byte ranges per readv.
            # The scheduler does NOT coalesce across the ~64 KiB record
            # gaps (gap > max_gap), so data reads stay ~n·10 bytes — but
            # each readv is one transaction instead of RECORD_BATCH.
            keys = []
            for lo in range(0, n_rec, RECORD_BATCH):
                idxs = range(lo, min(lo + RECORD_BATCH, n_rec))
                ranges = [(i * rb, 10) for i in idxs]
                for i, k in zip(idxs, rdr.handle.readv(ranges)):
                    keys.append((k, i))
            buckets: List[List[tuple]] = [[] for _ in range(n_buckets)]
            for k, i in keys:
                buckets[_bucket_of(k, n_buckets)].append((k, i))

        # bucket files never materialize: sort key lists directly and
        # paste straight into the output — the "buckets" are metadata
        with timer.lap("sorting"):
            for b in range(n_buckets):
                buckets[b].sort()

        with timer.lap("merging"):
            order = [i for b in range(n_buckets) for _, i in buckets[b]]
            yanked = rdr.yank_record_runs([(i, 1) for i in order])
            with fs.open_file("/output", "w") as out:
                out.pastev(yanked)

        io = wtf_io(cluster)
        outf = RecordFile(fs, "/output", rb)
        prev = b""
        for i in range(0, n_rec, max(1, n_rec // 64)):
            k = _key(outf.read_record(i))
            assert k >= prev, "output not sorted"
            prev = k
    return {"system": "wtf-keyonly", "stages_s": dict(timer.laps),
            "total_s": timer.total, **io}


# ----------------------------------------------------------------- HDFS
def hdfs_sort(scale: Scale, n_buckets: int = 8) -> dict:
    n_rec = scale.total_bytes // scale.record_bytes
    rb = scale.record_bytes
    records = _gen_records(n_rec, rb)
    timer = Timer()
    with hdfs_cluster(scale) as cluster:
        fs = cluster.client()
        w = fs.create("/input")
        for r in records:
            w.write(r)
        w.close()
        base = cluster.io_stats()

        with timer.lap("bucketing"):
            data = fs.read_all("/input")
            buckets: List[List[bytes]] = [[] for _ in range(n_buckets)]
            for i in range(n_rec):
                rec = data[i * rb:(i + 1) * rb]
                buckets[_bucket_of(_key(rec), n_buckets)].append(rec)
            for b, recs in enumerate(buckets):
                fs.write_all(f"/bucket_{b:03d}", b"".join(recs))

        with timer.lap("sorting"):
            for b in range(n_buckets):
                data = fs.read_all(f"/bucket_{b:03d}")
                recs = [data[i:i + rb] for i in range(0, len(data), rb)]
                recs.sort(key=_key)
                fs.write_all(f"/sorted_{b:03d}", b"".join(recs))

        with timer.lap("merging"):
            fs.concat([f"/sorted_{b:03d}" for b in range(n_buckets)],
                      "/output")

        io = cluster.io_stats()
        io = {k: io[k] - base[k] for k in io}
        out = fs.read_all("/output")
        prev = b""
        for i in range(n_rec):
            k = _key(out[i * rb:(i + 1) * rb])
            assert k >= prev, "output not sorted"
            prev = k
    return {"system": "hdfs-like", "stages_s": dict(timer.laps),
            "total_s": timer.total, **io}


def run(scale: Scale) -> dict:
    w = wtf_sort(scale)
    ko = wtf_sort_keyonly(scale)
    h = hdfs_sort(scale)
    total = scale.total_bytes
    result = {
        "scale": scale.name, "dataset_bytes": total,
        "wtf": w, "hdfs": h, "wtf_keyonly": ko,
        # Table 2 accounting, normalized to dataset size
        "wtf_read_x": w["bytes_read"] / total,
        "wtf_write_x": w["bytes_written"] / total,
        "hdfs_read_x": h["bytes_read"] / total,
        "hdfs_write_x": h["bytes_written"] / total,
        "keyonly_read_x": ko["bytes_read"] / total,
        "speedup": h["total_s"] / max(w["total_s"], 1e-9),
        "keyonly_speedup": h["total_s"] / max(ko["total_s"], 1e-9),
    }
    save_result("sort_mapreduce", result)
    print(f"[sort] dataset={fmt_bytes(total)}")
    print(f"[sort] WTF : R={result['wtf_read_x']:.2f}x "
          f"W={result['wtf_write_x']:.2f}x  t={w['total_s']:.2f}s "
          f"stages={ {k: round(v, 2) for k, v in w['stages_s'].items()} }")
    print(f"[sort] WTF-keyonly (beyond paper): "
          f"R={result['keyonly_read_x']:.4f}x W=0.00x "
          f"t={ko['total_s']:.2f}s")
    print(f"[sort] HDFS: R={result['hdfs_read_x']:.2f}x "
          f"W={result['hdfs_write_x']:.2f}x  t={h['total_s']:.2f}s "
          f"stages={ {k: round(v, 2) for k, v in h['stages_s'].items()} }")
    print(f"[sort] speedup: {result['speedup']:.2f}x paper-faithful, "
          f"{result['keyonly_speedup']:.2f}x key-only "
          f"(paper: 4x on 100 GB/15 nodes)")
    return result


if __name__ == "__main__":
    run(Scale.of("quick"))
