"""Concurrent relative appends (§2.5): commuting appends must not abort
each other; throughput scales with appenders instead of serializing."""
from __future__ import annotations

import threading
import time

from .common import Scale, save_result, wtf_cluster


def run(scale: Scale) -> dict:
    n_appenders = scale.n_clients
    n_appends = 64
    payload = b"a" * (64 << 10)
    rows = []
    for n in (1, n_appenders):
        with wtf_cluster(scale) as cluster:
            clients = [cluster.client() for _ in range(n)]
            fs0 = clients[0]
            fd0 = fs0.open("/log", "w")
            fs0.close(fd0)

            def work(i):
                c = clients[i]
                fd = c.open("/log", "a")       # append mode: no truncate
                for _ in range(n_appends):
                    c.append(fd, payload)
                c.close(fd)

            threads = [threading.Thread(target=work, args=(i,))
                       for i in range(n)]
            t0 = time.perf_counter()
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            secs = time.perf_counter() - t0
            size = clients[0].file_length("/log")
            expect = n * n_appends * len(payload)
            assert size == expect, (size, expect)
            kv = cluster.kv.stats.snapshot()
            rows.append({"appenders": n,
                         "appends_per_s": n * n_appends / secs,
                         "throughput_mbs": expect / secs / 1e6,
                         "kv_conflicts": kv.get("conflicts", 0)})
            print(f"[append] {n} appenders: "
                  f"{rows[-1]['appends_per_s']:.0f} appends/s, "
                  f"{rows[-1]['throughput_mbs']:.0f} MB/s, "
                  f"kv_conflicts={rows[-1]['kv_conflicts']}")
    out = {"rows": rows,
           "parallel_speedup": rows[-1]["appends_per_s"]
           / max(rows[0]["appends_per_s"], 1e-9)}
    save_result("append_bench", out)
    return out


if __name__ == "__main__":
    run(Scale.of("quick"))
