"""Concurrent relative appends (§2.5): commuting appends must not abort
each other; throughput scales with appenders instead of serializing.

Appenders open the log with ``"a"`` (O_APPEND) and call plain ``write`` —
the POSIX path that used to do a positional write at the EOF each fd cached
at open, silently overwriting concurrent appenders.  It now routes through
the commutative relative append, so the bench asserts BOTH halves of the
contract: no bytes lost (exact file length) and no OCC conflicts.

The per-row diagnostics localize any future serialization point:

  commit_wait_s       time committers spent queued for the group-commit
                      leader (convoy symptom);
  commit_hold_s       time leaders spent inside the commit critical
                      section (the shared resource itself);
  leader_drains       group-commit batches — appenders/drain > 1 means
                      followers piggyback instead of queueing;
  append_lock_wait_s  pure queueing on the storage append reservation
                      lock (data-plane symptom).

``storage_service_time`` models a real per-round storage RTT; without it
the in-process store round is a few µs of released-GIL syscall and thread
scheduling noise swamps the overlap being measured.
"""
from __future__ import annotations

import threading
import time

from .common import Scale, save_result, wtf_cluster

STORAGE_RTT_S = 1e-3           # modeled per-round storage service time
SWEEP = (1, 2, 4, 8)
MIN_PARALLEL_SPEEDUP = 1.5     # 2-appender gate (CI asserts it too)


def run(scale: Scale) -> dict:
    n_appends = {"smoke": 32, "quick": 64, "full": 128}[scale.name]
    payload = b"a" * (64 << 10)
    # One region holds the whole sweep: growing ``max_region`` is a
    # structural inode change (it must serialize against truncate), so a
    # region crossing costs one conflict burst among the racers.  §2.5's
    # zero-conflict claim is per region; size the log so the timed phase
    # never crosses.
    log_region = max(SWEEP) * n_appends * len(payload) + (1 << 20)
    rows = []
    for n in SWEEP:
        with wtf_cluster(scale,
                         storage_service_time=STORAGE_RTT_S) as cluster:
            clients = [cluster.client() for _ in range(n)]
            fd0 = clients[0].open("/log", "w", region_size=log_region)
            clients[0].close(fd0)
            # Warm the log: the first-ever append flips max_region -1 -> 0
            # (structural), which races once per file.  Not part of the
            # steady-state behavior being measured.
            wfd = clients[0].open("/log", "a")
            clients[0].write(wfd, b"w")
            clients[0].close(wfd)

            barrier = threading.Barrier(n)

            def work(i):
                c = clients[i]
                fd = c.open("/log", "a")       # O_APPEND: no truncate
                barrier.wait()
                for _ in range(n_appends):
                    c.write(fd, payload)
                c.close(fd)

            threads = [threading.Thread(target=work, args=(i,))
                       for i in range(n)]
            t0 = time.perf_counter()
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            secs = time.perf_counter() - t0
            size = clients[0].file_length("/log")
            expect = 1 + n * n_appends * len(payload)   # +1 warmup byte
            assert size == expect, \
                f"lost appended bytes: file={size} expected={expect}"
            s = cluster.total_stats()
            kv = s["kv"]
            assert kv.get("conflicts", 0) == 0, \
                f"{kv['conflicts']} OCC conflicts among commuting appends"
            rows.append({
                "appenders": n,
                "appends_per_s": n * n_appends / secs,
                "throughput_mbs": n * n_appends * len(payload) / secs / 1e6,
                "kv_conflicts": kv.get("conflicts", 0),
                "kv_aborts": kv.get("aborts", 0),
                "leader_drains": kv.get("leader_drains", 0),
                "commit_wait_s": round(kv.get("commit_wait_s", 0.0), 6),
                "commit_hold_s": round(kv.get("commit_hold_s", 0.0), 6),
                "append_lock_wait_s": round(s["append_lock_wait_s"], 6),
            })
            r = rows[-1]
            print(f"[append] {n} appenders: "
                  f"{r['appends_per_s']:.0f} appends/s, "
                  f"{r['throughput_mbs']:.0f} MB/s, "
                  f"conflicts={r['kv_conflicts']}, "
                  f"drains={r['leader_drains']}, "
                  f"wait={r['commit_wait_s']*1e3:.1f}ms "
                  f"hold={r['commit_hold_s']*1e3:.1f}ms "
                  f"lockwait={r['append_lock_wait_s']*1e3:.2f}ms")

    base = max(rows[0]["appends_per_s"], 1e-9)
    for r in rows:
        r["speedup"] = round(r["appends_per_s"] / base, 3)
    # Monotone scaling: more appenders must never LOWER total throughput
    # (5% tolerance for scheduler noise at these run lengths).
    for prev, cur in zip(rows, rows[1:]):
        assert cur["appends_per_s"] >= 0.95 * prev["appends_per_s"], (
            f"appends/s regressed {prev['appenders']}->{cur['appenders']} "
            f"appenders: {prev['appends_per_s']:.0f} -> "
            f"{cur['appends_per_s']:.0f}")
    out = {"rows": rows,
           "parallel_speedup": rows[1]["speedup"],     # 2 appenders vs 1
           "max_speedup": rows[-1]["speedup"]}
    assert out["parallel_speedup"] >= MIN_PARALLEL_SPEEDUP, (
        f"2-appender speedup {out['parallel_speedup']:.2f} < "
        f"{MIN_PARALLEL_SPEEDUP}: appends are serializing")
    print(f"[append] parallel_speedup(2)={out['parallel_speedup']:.2f} "
          f"max_speedup({SWEEP[-1]})={out['max_speedup']:.2f}")
    save_result("append_bench", out)
    return out


if __name__ == "__main__":
    import sys
    run(Scale.of(sys.argv[1] if len(sys.argv) > 1 else "quick"))
