"""Benchmark driver: one module per paper table/figure.

  python -m benchmarks.run [--scale quick|full] [--only sort,gc,...]

Writes benchmarks/results/<name>.json per benchmark and prints a summary
validating each reproduction claim against the paper.
"""
from __future__ import annotations

import argparse
import sys
import time

from .common import Scale

BENCHES = {
    "sort": ("Table 2 + Figs 4-5 (map-reduce sort, file slicing)",
             "benchmarks.sort_mapreduce"),
    "single_server": ("Fig 6 (one-node baseline vs local FS)",
                      "benchmarks.single_server"),
    "seq_write": ("Figs 7-8 (sequential write throughput/latency)",
                  "benchmarks.seq_write"),
    "random_write": ("Figs 9-10 (random-offset writes)",
                     "benchmarks.random_write"),
    "read": ("Figs 11-12 (sequential/random reads)",
             "benchmarks.read_bench"),
    "write_sched": ("write-path scheduler (scalar vs batched stores)",
                    "benchmarks.write_bench"),
    "write_behind": ("write-behind buffer (many small ops per txn)",
                     "benchmarks.write_bench", "run_smallops"),
    "meta": ("metadata-plane fast path (commit-time compaction, "
             "scatter-gather retrieval, KV group commit)",
             "benchmarks.meta_bench"),
    "scaling": ("Figs 13-14 (client scaling: metadata ops/s vs shard "
                "count 1/2/4, leases off/on)", "benchmarks.scaling"),
    "gc": ("Fig 15 (garbage-collection rate)", "benchmarks.gc_bench"),
    "append": ("§2.5 (concurrent relative appends)",
               "benchmarks.append_bench"),
    "wlog": ("streaming multi-producer log over one file (§2.5 + "
             "bounded-WAL subscribe tailing)", "benchmarks.wlog_bench"),
    "pipeline": ("beyond-paper (shuffle/checkpoint/reshard zero-copy)",
                 "benchmarks.pipeline_bench"),
    "pipeline_overlap": ("async I/O runtime (sync vs async prefetch "
                         "overlap, plan-cache re-reads)",
                         "benchmarks.pipeline_bench", "run_overlap"),
    "repair": ("§2.9 failure domain (kill 1 of N mid-workload: zero data "
               "loss, time-to-full-replication)",
               "benchmarks.repair_bench"),
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="quick",
                    choices=["smoke", "quick", "full"])
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: "
                    + ",".join(BENCHES))
    args = ap.parse_args(argv)
    scale = Scale.of(args.scale)
    names = (args.only.split(",") if args.only else list(BENCHES))
    unknown = [n for n in names if n not in BENCHES]
    if unknown:
        ap.error(f"unknown benchmark name(s) {', '.join(unknown)}; "
                 f"valid names: {', '.join(sorted(BENCHES))}")

    t0 = time.time()
    failures = []
    for name in names:
        desc, mod_name, *fn_name = BENCHES[name]
        print(f"\n=== {name}: {desc} ===", flush=True)
        try:
            import importlib
            mod = importlib.import_module(mod_name)
            getattr(mod, fn_name[0] if fn_name else "run")(scale)
        except Exception as e:                    # noqa: BLE001
            import traceback
            traceback.print_exc()
            failures.append((name, repr(e)))
    print(f"\n[benchmarks] done in {time.time() - t0:.0f}s; "
          f"{len(names) - len(failures)}/{len(names)} passed")
    for name, err in failures:
        print(f"[benchmarks] FAILED {name}: {err}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
