"""Streaming multi-producer log (``core.wlog``): the workload the
unserialized append path opens up.

N producers append length-prefixed records to ONE log file concurrently —
every batch is a §2.5 commutative relative append, so producers never
conflict — while M consumers tail the committed prefix through the
bounded-WAL ``subscribe`` stream (no length polling).  A further LATE
consumer attaches only after production finished and must catch up purely
from the WAL snapshot replay.

Asserted, per cluster configuration (metadata shards 1/2 x leases off/on):

  * every consumer delivers exactly N*R records, in per-producer FIFO
    order, with a byte-identical delivery stream (same payloads, same
    file order) across all consumers — including the late one;
  * consumers end exactly at the file's committed length.

``kv_conflicts`` is reported, not asserted zero: producers never conflict
with each other (§2.5 — the append bench asserts that in isolation), but
a tailing consumer's ``pread`` carries a read dependency on the region,
so a read racing a commit occasionally revalidates.  Those retries are
invisible to delivery (the counts/digests above still hold exactly).

Across configurations the file-order interleaving legitimately differs,
so the cross-config check is the order-independent ``content_digest`` of
the delivered record multiset: all four configurations must deliver the
exact same records, byte for byte.
"""
from __future__ import annotations

import threading
import time

from repro.core.wlog import WtfLog, content_digest

from .common import Scale, save_result, wtf_cluster

N_PRODUCERS = 4
N_CONSUMERS = 2                 # tailing from the start; +1 late consumer
BATCH_RECORDS = 4               # records per append (one txn per batch)
CONSUME_DEADLINE_S = 120.0
CONFIGS = ((1, None), (1, 0.5), (2, None), (2, 0.5))


def _record(producer: int, seq: int, pad: int) -> bytes:
    return f"p{producer:02d}s{seq:06d}|".encode() + b"x" * pad


def _check_fifo(payloads) -> None:
    last = {}
    for p in payloads:
        head = bytes(p[:12]).decode()        # pPPsSSSSSS|
        prod, seq = int(head[1:3]), int(head[4:10])
        assert seq == last.get(prod, -1) + 1, (
            f"producer {prod} out of order: {seq} after {last.get(prod)}")
        last[prod] = seq


def run(scale: Scale) -> dict:
    n_records = {"smoke": 48, "quick": 150, "full": 400}[scale.name]
    pad = 120
    want = N_PRODUCERS * n_records + 1        # +1 warmup record
    rows = []
    contents = []
    for shards, lease in CONFIGS:
        with wtf_cluster(scale, n_meta_shards=shards,
                         lease_ttl=lease) as cluster:
            log = WtfLog(cluster, "/stream")
            # Warmup: the log's first-ever append flips max_region -1 -> 0
            # (structural) and may race; commit it before the timed phase
            # so steady-state producers are conflict-free.  Deterministic,
            # so it is part of every configuration's record multiset.
            w = log.producer()
            w.produce(_record(99, 0, pad))
            w.close()

            consumers = [log.consumer() for _ in range(N_CONSUMERS)]
            streams = [[] for _ in range(N_CONSUMERS)]

            def consume(c, out):
                deadline = time.monotonic() + CONSUME_DEADLINE_S
                while c.records < want and time.monotonic() < deadline:
                    out.extend(c.poll(timeout=0.5))

            ctreads = [threading.Thread(target=consume, args=(c, out))
                       for c, out in zip(consumers, streams)]
            for t in ctreads:
                t.start()

            producers = [log.producer(batch_records=BATCH_RECORDS)
                         for _ in range(N_PRODUCERS)]
            barrier = threading.Barrier(N_PRODUCERS)

            def produce(i):
                barrier.wait()
                for j in range(n_records):
                    producers[i].produce(_record(i, j, pad))
                producers[i].close()

            pthreads = [threading.Thread(target=produce, args=(i,))
                        for i in range(N_PRODUCERS)]
            t0 = time.perf_counter()
            for t in pthreads:
                t.start()
            for t in pthreads:
                t.join()
            produce_secs = time.perf_counter() - t0
            for t in ctreads:
                t.join()
            drain_secs = time.perf_counter() - t0

            # Late consumer: attaches after ALL commits; its watermark
            # comes entirely from the WAL snapshot replay.
            late = log.consumer()
            late_stream = []
            consume(late, late_stream)
            consumers.append(late)
            streams.append(late_stream)

            kv = cluster.total_stats()["kv"]
            length = cluster.client().file_length("/stream")
            digests = [c.digest() for c in consumers]
            for c, stream in zip(consumers, streams):
                assert c.records == want, \
                    f"consumer delivered {c.records}/{want} records"
                assert c.position == length, \
                    f"cursor {c.position} != committed length {length}"
                _check_fifo(stream)
            assert len(set(digests)) == 1, \
                f"consumers diverged: {digests}"
            for c in consumers:
                c.close()

            contents.append(content_digest(streams[0]))
            rows.append({
                "n_meta_shards": shards,
                "lease_ttl": lease,
                "producers": N_PRODUCERS,
                "consumers": N_CONSUMERS + 1,
                "records": want,
                "produce_records_per_s": round(
                    N_PRODUCERS * n_records / produce_secs, 1),
                "drain_secs": round(drain_secs, 3),
                "flushes": sum(p.flushes for p in producers),
                "kv_conflicts": kv.get("conflicts", 0),
                "delivery_digest": digests[0],
                "content_digest": contents[-1],
            })
            r = rows[-1]
            print(f"[wlog] shards={shards} lease={lease}: "
                  f"{r['produce_records_per_s']:.0f} rec/s produced, "
                  f"{r['records']} delivered x{r['consumers']} consumers, "
                  f"conflicts={r['kv_conflicts']}, "
                  f"content={r['content_digest'][:12]}…")

    assert len(set(contents)) == 1, (
        f"record multiset differs across configurations: {contents}")
    out = {"rows": rows,
           "cross_config_content_match": True,
           "content_digest": contents[0]}
    print(f"[wlog] all {len(CONFIGS)} configurations delivered the same "
          f"record multiset: {contents[0][:16]}…")
    save_result("wlog_bench", out)
    return out


if __name__ == "__main__":
    import sys
    run(Scale.of(sys.argv[1] if len(sys.argv) > 1 else "quick"))
