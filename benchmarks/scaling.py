"""Many-client metadata ops/s vs. shard count and leases (Figs 13-14).

The paper scales clients against a HyperDex Warp *ensemble*; the in-process
stand-in is the sharded metadata plane (``core/mdshard``) plus leased
client caching (``core/lease``).  This benchmark makes the scaling claim
physically measurable by running every cluster with a modeled per-request
metadata service time (``kv_service_time``): each shard serializes its own
requests on one service lock while sleeping with the GIL released, so

  * N shards genuinely serve ~N clients' metadata reads concurrently, and
  * a lease-served read skips the round trip (and its delay) entirely.

Workload: W client threads each own F files and hammer ``stat`` over them
— the pure metadata hot loop (path lookup + inode + region length), with
all data I/O out of the picture.  File names are chosen balanced across 4
(hence also 2) shards, so the sweep measures sharding, not hash luck; the
SAME names and bytes are used for every configuration and the final
read-back digest must be byte-identical to the unsharded, lease-off run.

Sweep: shard count 1/2/4 (``n_meta_shards``) × leases off/on
(``lease_ttl``).  Asserted at every scale:

  * lease-off ops/s increases monotonically with shard count, and the
    4-shard plane is >= 2x the 1-shard plane;
  * with leases on, the timed hot loop issues ZERO KV round trips
    (``gets``/``commits`` deltas are exactly 0, ``lease_hits`` > 0);
  * the hot loop stays single-shard (no 2PC counters move while timing).
"""
from __future__ import annotations

import hashlib
import sys
import threading
import time

from repro.core.placement import stable_hash

from .common import Scale, save_result, wtf_cluster

SHARD_SWEEP = (1, 2, 4)
LEASE_TTL = 60.0
SERVICE_TIME_S = 0.0005        # one modeled metadata round trip
FILES_PER_CLIENT = 4


def _params(scale: Scale) -> tuple:
    """(threads, stat passes per thread) by scale."""
    if scale.name == "smoke":
        return 6, 25
    if scale.name == "full":
        return 8, 120
    return 8, 50


def _balanced_paths(n_files: int) -> list:
    """File names spread exactly evenly over 4 metadata shards (and hence
    over 2): the sweep should measure sharding, not hash luck.  Uses the
    same routing hash as ``ShardedKV.shard_index``."""
    buckets: dict = {0: [], 1: [], 2: [], 3: []}
    need = (n_files + 3) // 4
    i = 0
    while min(len(b) for b in buckets.values()) < need:
        name = f"/s{i:04d}"
        buckets[stable_hash("paths", name, salt="mdshard") % 4].append(name)
        i += 1
    return [buckets[j % 4][j // 4] for j in range(n_files)]


def _content(path: str) -> bytes:
    return (path.encode() + b"|") * 32


def _run_config(scale: Scale, n_shards: int, leases: bool) -> dict:
    threads, iters = _params(scale)
    paths = _balanced_paths(threads * FILES_PER_CLIENT)
    # Round-robin assignment: each thread's file set spans the shards too.
    mine = {t: paths[t::threads] for t in range(threads)}

    kw = dict(n_meta_shards=n_shards, kv_service_time=SERVICE_TIME_S)
    if leases:
        kw["lease_ttl"] = LEASE_TTL
    with wtf_cluster(scale, **kw) as cluster:
        clients = {t: cluster.client() for t in range(threads)}

        def setup(t):
            c = clients[t]
            for p in mine[t]:
                fd = c.open(p, "w")
                c.write(fd, _content(p))
                c.close(fd)
                c.stat(p)          # warm: grants leases, pins versions

        def hot(t):
            c = clients[t]
            for _ in range(iters):
                for p in mine[t]:
                    c.stat(p)

        def fanout(fn):
            ts = [threading.Thread(target=fn, args=(t,))
                  for t in range(threads)]
            for th in ts:
                th.start()
            for th in ts:
                th.join()

        fanout(setup)

        before = cluster.total_stats()
        t0 = time.perf_counter()
        fanout(hot)
        wall = time.perf_counter() - t0
        after = cluster.total_stats()

        ops = threads * iters * FILES_PER_CLIENT
        row = {
            "shards": n_shards,
            "leases": leases,
            "ops": ops,
            "wall_s": wall,
            "opss": ops / wall,
            "kv_gets_delta": after["kv"]["gets"] - before["kv"]["gets"],
            "kv_commits_delta": (after["kv"]["commits"]
                                 - before["kv"]["commits"]),
        }
        if n_shards > 1:
            row["mdshard"] = after["mdshard"]
            row["cross_shard_delta"] = (
                after["mdshard"]["cross_shard_commits"]
                - before["mdshard"]["cross_shard_commits"])
        if leases:
            row["lease_stats"] = after["leases"]

        # Byte-identical verification: a fresh client reads every file.
        verifier = cluster.client()
        h = hashlib.blake2b(digest_size=16)
        for p in sorted(paths):
            fd = verifier.open(p, "r")
            data = verifier.read(fd)
            verifier.close(fd)
            h.update(p.encode() + b"=" + data + b";")
        row["digest"] = h.hexdigest()
        return row


def run(scale: Scale) -> dict:
    rows = []
    for n_shards in SHARD_SWEEP:
        for leases in (False, True):
            row = _run_config(scale, n_shards, leases)
            rows.append(row)
            extra = ""
            if leases:
                ls = row["lease_stats"]
                extra = (f", lease_hits={ls['lease_hits']}, "
                         f"kv gets delta={row['kv_gets_delta']}")
            print(f"[scaling] shards={n_shards} leases={leases!s:5}: "
                  f"{row['opss']:8.0f} ops/s ({row['wall_s']:.2f}s)"
                  f"{extra}")

    by = {(r["shards"], r["leases"]): r for r in rows}

    # Correctness: every configuration returns byte-identical file data
    # to the unsharded, lease-off plane.
    base_digest = by[(1, False)]["digest"]
    assert all(r["digest"] == base_digest for r in rows), \
        "configurations diverged: " \
        + str([(r["shards"], r["leases"], r["digest"]) for r in rows])

    # Scaling: lease-off ops/s strictly increases with shard count, and
    # 4 shards clear 2x the single-shard plane.
    off = [by[(n, False)]["opss"] for n in SHARD_SWEEP]
    assert off[0] < off[1] < off[2], \
        f"ops/s not monotonic in shard count: {off}"
    speedup = off[2] / off[0]
    assert speedup >= 2.0, f"4-shard speedup {speedup:.2f}x < 2x"

    # Leases: the hot loop re-reads unchanged files with ZERO KV round
    # trips — request counters flat, hits observed, commits skipped.
    for n in SHARD_SWEEP:
        r = by[(n, True)]
        assert r["kv_gets_delta"] == 0, \
            f"{n}-shard lease run issued {r['kv_gets_delta']} KV gets"
        assert r["kv_commits_delta"] == 0, \
            f"{n}-shard lease run issued {r['kv_commits_delta']} KV commits"
        assert r["lease_stats"]["lease_hits"] > 0
        assert r["lease_stats"]["lease_commit_skips"] > 0
        assert r["opss"] > by[(n, False)]["opss"], \
            f"leases did not speed up the {n}-shard hot loop"
        if n > 1:
            assert r["cross_shard_delta"] == 0, \
                "hot single-file loop crossed shards"

    out = {"rows": rows, "scale": scale.name,
           "service_time_s": SERVICE_TIME_S,
           "speedup_4x1": speedup,
           "lease_speedup_1shard":
               by[(1, True)]["opss"] / by[(1, False)]["opss"]}
    print(f"[scaling] 4-shard/1-shard (leases off): {speedup:.2f}x; "
          f"leases on 1 shard: {out['lease_speedup_1shard']:.2f}x")
    save_result("scaling", out)
    return out


if __name__ == "__main__":
    run(Scale.of(sys.argv[1] if len(sys.argv) > 1 else "quick"))
