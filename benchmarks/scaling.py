"""Throughput/latency as the number of writers grows (Figs 13-14)."""
from __future__ import annotations

from .common import Scale, lat_summary, save_result, wtf_cluster, wtf_io
from .seq_write import _drive_writers

WRITE_SIZE = 4 << 20


def run(scale: Scale) -> dict:
    rows = []
    for n in (1, 2, scale.n_clients, scale.n_clients * 2):
        with wtf_cluster(scale) as cluster:
            clients = [cluster.client() for _ in range(n)]
            fds = [c.open(f"/s{i}", "w") for i, c in enumerate(clients)]

            def writer(i):
                return lambda buf: clients[i].write(fds[i], buf)

            secs, lats = _drive_writers(n, scale.total_bytes, WRITE_SIZE,
                                        writer)
            io = wtf_io(cluster)
            rows.append({"clients": n,
                         "throughput_mbs": io["bytes_written"] / secs / 1e6,
                         **lat_summary(lats)})
            print(f"[scaling] {n} clients: "
                  f"{rows[-1]['throughput_mbs']:.0f} MB/s, median "
                  f"{rows[-1]['median_ms']:.1f}ms")
    out = {"rows": rows, "scale": scale.name,
           "saturates": rows[-1]["throughput_mbs"]
           < 1.5 * rows[-2]["throughput_mbs"]}
    save_result("scaling", out)
    return out


if __name__ == "__main__":
    run(Scale.of("quick"))
