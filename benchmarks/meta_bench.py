"""Metadata-plane fast-path benchmark: the three commit/plan-path
optimizations measured against their own off switches.

  1. **Hot region** — a small-append + re-read stream into one region.
     Without the fast path every re-read re-resolves the region's entire
     overlay history (quadratic over the stream) and the list grows
     without bound; with commit-time compaction (``CompactRegion``) plus
     the delta-maintained resolved index the planning cost stays flat.
     Counters: ``kv.compactions`` > 0, ``resolved_index_hits`` > 0, final
     overlay length bounded by the threshold — and byte-identical reads.
  2. **Scatter-gather** — a vectored read of non-adjacent extents on one
     (server, backing file).  One ``retrieve_slices`` round with the fast
     path on vs. one round per coalesced run off; asserted strictly fewer
     server ``read_rounds`` with identical bytes and identical
     ``slices_read`` (no accounting drift).
  3. **Group commit** — concurrent auto-commit metadata ops.  With
     ``kv_group_commit`` the stripe-lock acquisition passes
     (``commit_lock_passes``) are strictly fewer than the commits they
     serve; off, they are equal.  Final file bytes identical either way.

Usage: ``python -m benchmarks.meta_bench [smoke|quick|full]``.  Saves
``results/meta_bench.json`` (the perf-trajectory artifact CI uploads).
"""
from __future__ import annotations

import dataclasses
import sys
import threading
import time

from repro.core.inode import region_key

from .common import Scale, fmt_bytes, save_result, wtf_cluster

APPEND_BYTES = 512
HOT_APPENDS = {"smoke": 256, "quick": 1024, "full": 4096}
REREAD_WINDOW = 16 << 10
SG_CHUNK = 8 << 10
SG_CHUNKS = {"smoke": 16, "quick": 48, "full": 128}
GC_THREADS = {"smoke": 4, "quick": 8, "full": 8}
GC_OPS = {"smoke": 150, "quick": 400, "full": 1200}


# --------------------------------------------------------------- scenario 1
def _drive_hot_region(cluster, n_appends: int):
    """Append small chunks to one file; re-read a fixed window after each.
    Returns (client, re-read wall seconds, final bytes, final entry count)."""
    fs = cluster.client()
    fd = fs.open("/hot", "w")
    reread_s = 0.0
    for i in range(n_appends):
        fs.append(fd, bytes([i % 256]) * APPEND_BYTES)
        t0 = time.perf_counter()
        fs.pread(fd, REREAD_WINDOW, 0)
        reread_s += time.perf_counter() - t0
    data = fs.pread(fd, n_appends * APPEND_BYTES, 0)
    fs.close(fd)
    ino = fs.stat("/hot")["inode"]
    rd = cluster.kv.get("regions", region_key(ino, 0))
    return fs, reread_s, data, len(rd.entries)


def _hot_region(scale: Scale) -> dict:
    n = HOT_APPENDS.get(scale.name, 1024)
    thr = 64
    row = {"n_appends": n, "append_bytes": APPEND_BYTES,
           "compact_threshold": thr}
    datas = {}
    for key, kw in (
            ("scalar", dict(resolved_index=False,
                            region_compact_threshold=None)),
            ("fast", dict(resolved_index=True,
                          region_compact_threshold=thr))):
        with wtf_cluster(dataclasses.replace(scale, n_servers=1),
                         **kw) as cluster:
            fs, reread_s, data, entries = _drive_hot_region(cluster, n)
            datas[key] = data
            row[key] = {
                "reread_wall_s": reread_s,
                "final_region_entries": entries,
                "kv_compactions": cluster.kv.stats.compactions,
                "kv_commits": cluster.kv.stats.commits,
                "resolved_index_hits": fs.stats.resolved_index_hits,
                "resolved_index_misses": fs.stats.resolved_index_misses,
            }
    row["speedup"] = (row["scalar"]["reread_wall_s"]
                      / max(row["fast"]["reread_wall_s"], 1e-9))
    s, f = row["scalar"], row["fast"]
    print(f"[meta/hot] {n}x{APPEND_BYTES}B appends + re-reads: scalar "
          f"{s['reread_wall_s']:.2f}s ({s['final_region_entries']} entries) "
          f"| fast {f['reread_wall_s']:.2f}s "
          f"({f['final_region_entries']} entries, "
          f"{f['kv_compactions']} compactions, "
          f"{f['resolved_index_hits']} index hits) | "
          f"{row['speedup']:.2f}x")
    assert datas["fast"] == datas["scalar"], \
        "fast metadata path must read back byte-identical content"
    assert f["kv_compactions"] > 0, \
        "hot-region stream must trigger commit-time compactions"
    assert f["resolved_index_hits"] > 0, \
        "hot-region re-reads must hit the resolved index"
    assert f["final_region_entries"] <= thr + 1, (
        "commit-time compaction must bound the overlay list near the "
        f"threshold: {f['final_region_entries']} entries > {thr + 1}")
    assert s["final_region_entries"] >= n, \
        "scalar baseline should accumulate the full overlay history"
    return row


# --------------------------------------------------------------- scenario 2
def _drive_sg(cluster, k: int):
    """Interleave two files into one backing file so /a's slices are
    non-adjacent on disk, then vector-read all of /a's chunks."""
    fs = cluster.client()
    fa = fs.open("/a", "w")
    fb = fs.open("/b", "w")
    for i in range(k):
        fs.pwrite(fa, bytes([i % 256]) * SG_CHUNK, i * SG_CHUNK)
        fs.pwrite(fb, b"\xee" * SG_CHUNK, i * SG_CHUNK)
    cluster.reset_io_stats()
    out = fs.readv(fa, [(i * SG_CHUNK, SG_CHUNK) for i in range(k)])
    st = cluster.total_stats()
    rounds = sum(s["read_rounds"] for s in st["servers"].values())
    return fs, b"".join(out), rounds, st["slices_read"]


def _scatter_gather(scale: Scale) -> dict:
    k = SG_CHUNKS.get(scale.name, 48)
    row = {"n_chunks": k, "chunk_bytes": SG_CHUNK}
    datas = {}
    for key, on in (("scalar", False), ("sg", True)):
        # one server + one backing file + 1-byte gap: every chunk of /a is
        # its own coalesced run, so rounds are fully determined by the knob
        with wtf_cluster(dataclasses.replace(scale, n_servers=1),
                         num_backing_files=1,
                         fetch_gap_bytes=1, scatter_gather=on) as cluster:
            fs, data, rounds, slices = _drive_sg(cluster, k)
            datas[key] = data
            row[key] = {"read_rounds": rounds, "slices_read": slices,
                        "fetch_batches": fs.stats.fetch_batches,
                        "slices_coalesced": fs.stats.slices_coalesced}
    print(f"[meta/sg] {k}x{fmt_bytes(SG_CHUNK)} non-adjacent read: "
          f"{row['scalar']['read_rounds']} rounds -> "
          f"{row['sg']['read_rounds']} with retrieve_slices "
          f"(slices_read {row['sg']['slices_read']} both ways)")
    assert datas["sg"] == datas["scalar"], \
        "scatter-gather retrieval must return byte-identical content"
    assert row["sg"]["read_rounds"] < row["scalar"]["read_rounds"], (
        "retrieve_slices must cost strictly fewer storage rounds for a "
        "non-adjacent multi-extent read")
    assert row["sg"]["slices_read"] == row["scalar"]["slices_read"], \
        "slices_read (pointer retrievals served) must not drift"
    return row


# --------------------------------------------------------------- scenario 3
def _drive_group_commit(cluster, n_threads: int, n_ops: int):
    """Concurrent auto-commit punch ops: pure metadata commits, the
    convoy-on-stripe-locks shape group commit exists for."""
    size = n_threads * n_ops * 2
    setup = cluster.client()
    fd = setup.open("/gc", "w")
    setup.write(fd, b"\xab" * size)
    setup.close(fd)
    clients = [cluster.client() for _ in range(n_threads)]
    kv0 = cluster.kv.stats.snapshot()

    def work(i):
        fs = clients[i]
        fd = fs.open("/gc", "rw")
        for j in range(n_ops):
            fs.seek(fd, (i * n_ops + j) * 2)
            fs.punch(fd, 1)          # one auto-commit metadata-only op
        fs.close(fd)

    threads = [threading.Thread(target=work, args=(i,))
               for i in range(n_threads)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    kv1 = cluster.kv.stats.snapshot()
    reader = cluster.client()
    fd = reader.open("/gc", "r")
    data = reader.read(fd)
    reader.close(fd)
    return {"wall_s": wall,
            "commits": kv1["commits"] - kv0["commits"],
            "aborts": kv1["aborts"] - kv0["aborts"],
            "lock_passes": (kv1["commit_lock_passes"]
                            - kv0["commit_lock_passes"]),
            "grouped_commits": (kv1["grouped_commits"]
                                - kv0["grouped_commits"])}, data


def _group_commit(scale: Scale) -> dict:
    n_threads = GC_THREADS.get(scale.name, 8)
    n_ops = GC_OPS.get(scale.name, 400)
    row = {"n_threads": n_threads, "ops_per_thread": n_ops}
    datas = {}
    for key, on in (("scalar", False), ("grouped", True)):
        with wtf_cluster(scale, kv_group_commit=on) as cluster:
            row[key], datas[key] = _drive_group_commit(cluster, n_threads,
                                                       n_ops)
    s, g = row["scalar"], row["grouped"]
    print(f"[meta/gc] {n_threads}x{n_ops} concurrent auto-commit ops: "
          f"lock passes {s['lock_passes']}/{s['commits']} commits -> "
          f"{g['lock_passes']}/{g['commits']} "
          f"({g['grouped_commits']} grouped)")
    assert datas["grouped"] == datas["scalar"], \
        "group commit must not change committed content"
    assert s["lock_passes"] == s["commits"] + s["aborts"], \
        "without group commit every commit attempt is its own lock pass"
    assert g["lock_passes"] < g["commits"], (
        "concurrent auto-commit ops must share stripe-lock acquisition "
        "passes under group commit")
    return row


def run(scale: Scale) -> dict:
    out = {"scale": scale.name}
    out["hot_region"] = _hot_region(scale)
    out["scatter_gather"] = _scatter_gather(scale)
    out["group_commit"] = _group_commit(scale)
    save_result("meta_bench", out)
    return out


if __name__ == "__main__":
    run(Scale.of(sys.argv[1] if len(sys.argv) > 1 else "quick"))
