"""Beyond-paper: the WTF substrate under the training stack.

  * zero-copy global shuffle of a token dataset (epoch files) vs a naive
    read-everything/rewrite shuffle;
  * incremental checkpointing (slice sharing) and zero-copy RESHARD
    (256→512-host style re-partition) vs full rewrite;
  * the **overlap scenario** (``run_overlap`` / ``pipeline_overlap``):
    sync vs async prefetch over identical batch streams — the unified
    I/O runtime's futures surface hiding storage rounds behind compute.
"""
from __future__ import annotations

import sys
import time

import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data.records import RecordFile, write_token_shard
from repro.data.shuffle import shuffle_epoch

from .common import Scale, fmt_bytes, save_result, wtf_cluster, wtf_io


def run(scale: Scale) -> dict:
    out = {}
    block_tokens = 512
    n_tokens = min(scale.total_bytes // 8, 2 << 20)
    with wtf_cluster(scale) as cluster:
        fs = cluster.client()
        fs.mkdir("/data")
        rng = np.random.RandomState(0)
        spec = write_token_shard(fs, "/data/shard0",
                                 iter(rng.randint(0, 50000, n_tokens)),
                                 block_tokens)
        cluster.reset_io_stats()

        t0 = time.perf_counter()
        n_shuffled = shuffle_epoch(fs, ["/data/shard0"], "/data/epoch0",
                                   block_tokens * 4, seed=1)
        secs = time.perf_counter() - t0
        assert n_shuffled == spec.count
        io = wtf_io(cluster)
        out["shuffle"] = {
            "records": spec.count, "wall_s": secs,
            "data_bytes_moved": io["bytes_read"] + io["bytes_written"],
            "naive_bytes": 2 * spec.count * spec.record_bytes,
        }
        print(f"[pipeline] zero-copy shuffle of {spec.count} records: "
              f"{fmt_bytes(out['shuffle']['data_bytes_moved'])} moved "
              f"(naive: {fmt_bytes(out['shuffle']['naive_bytes'])}), "
              f"{secs:.2f}s")

        # ---- checkpoint: save, incremental save, reshard.  All four
        # "hosts" write their shards; host 0 commits last (the barrier).
        mgr = CheckpointManager(fs, "/ckpt")
        tree = {"w": np.random.RandomState(1).rand(256, 1024),
                "b": np.random.RandomState(2).rand(1024),
                "frozen": np.random.RandomState(3).rand(512, 512)}

        def save_all_hosts(step, t, prev=None):
            stats = None
            for h in (1, 2, 3, 0):
                s = mgr.save(step, t, host_id=h, num_hosts=4,
                             prev_step=prev)
                if h == 0:
                    stats = s
                else:
                    stats = s if stats is None else {
                        k: stats.get(k, 0) + v for k, v in s.items()}
            return stats

        s1 = save_all_hosts(100, tree)
        tree2 = dict(tree)
        tree2["w"] = tree["w"] + 1.0          # only w changed
        s2 = save_all_hosts(200, tree2, prev=100)
        cluster.reset_io_stats()
        t0 = time.perf_counter()
        mgr.reshard(200, new_shards=8, dst_step=300)
        rs = time.perf_counter() - t0
        io = wtf_io(cluster)
        restored = mgr.restore(tree2, step=300)
        assert np.allclose(restored["w"], tree2["w"])
        out["checkpoint"] = {
            "full_save_bytes": s1["bytes_written"],
            "incremental_save_bytes": s2["bytes_written"],
            "incremental_shared_bytes": s2["bytes_shared"],
            "reshard_data_bytes": io["bytes_read"] + io["bytes_written"],
            "reshard_wall_s": rs,
        }
        print(f"[pipeline] ckpt full={fmt_bytes(s1['bytes_written'])} "
              f"incr={fmt_bytes(s2['bytes_written'])} "
              f"(shared {fmt_bytes(s2['bytes_shared'])}); 4→8-host "
              f"reshard moved {fmt_bytes(out['checkpoint']['reshard_data_bytes'])} "
              f"in {rs:.2f}s")
    save_result("pipeline_bench", out)
    return out


def run_overlap(scale: Scale) -> dict:
    """Sync vs async prefetch over identical pipeline batch streams.

    Two comparisons, both against the same shuffled epoch file:

    1. **End-to-end pipeline.**  ``DataPipeline`` consumed with a small
       simulated compute step per batch, once with ``async_prefetch=False``
       (each window's plan+fetch serializes with consumption — one blocked
       wait per window) and once with the issue-ahead async prefetcher.
       Asserts the streams are byte-identical (zero stale reads) and that
       async blocks strictly fewer times.
    2. **Fixed window list.**  The pipeline's exact window access pattern
       driven directly through ``RecordFile`` so the window count is
       deterministic: async must issue NO more storage rounds than sync
       over the same windows, while blocking strictly less.  A second
       async pass over the same windows must hit the read-plan cache.
    """
    import dataclasses

    from repro.data.pipeline import (DataPipeline, PipelineConfig,
                                     PipelineState)

    block_tokens = 128
    n_tokens = min(scale.total_bytes // 16, 1 << 18)
    compute_s = 0.005                      # simulated per-batch step time
    n_batches = 24
    out = {"scale": scale.name}
    with wtf_cluster(scale) as cluster:
        fs = cluster.client()
        fs.mkdir("/data")
        rng = np.random.RandomState(0)
        write_token_shard(fs, "/data/shard0",
                          iter(rng.randint(0, 50000, n_tokens)),
                          block_tokens)
        base_cfg = PipelineConfig(
            src_paths=("/data/shard0",), work_dir="/data/epochs",
            block_tokens=block_tokens, global_batch=8, seed=1,
            prefetch=4, async_prefetch=False)

        # ---- 1. end-to-end DataPipeline, sync vs async prefetch
        streams, results = {}, {}
        for key, async_on in (("sync", False), ("async", True)):
            cfg = dataclasses.replace(base_cfg, async_prefetch=async_on)
            pipe = DataPipeline(fs, cfg, state=PipelineState(0, 0))
            it = iter(pipe)
            before = fs.stats.snapshot()
            t0 = time.perf_counter()
            toks = []
            for _ in range(n_batches):
                batch = next(it)
                toks.append(np.array(batch["tokens"]))
                time.sleep(compute_s)
            secs = time.perf_counter() - t0
            it.close()                     # joins the producer: quiescent
            after = fs.stats.snapshot()
            streams[key] = toks
            results[key] = {
                "wall_s": secs,
                "blocked_waits":
                    after["blocked_waits"] - before["blocked_waits"],
                "fetch_batches":
                    after["fetch_batches"] - before["fetch_batches"],
            }
        assert all(np.array_equal(a, b) for a, b in
                   zip(streams["sync"], streams["async"])), \
            "async prefetch must deliver the identical batch stream"
        s, a = results["sync"], results["async"]
        assert a["blocked_waits"] < s["blocked_waits"], (
            f"async prefetch must block strictly less: "
            f"{a['blocked_waits']} vs {s['blocked_waits']}")
        out["pipeline"] = {"sync": s, "async": a,
                           "overlap_speedup": s["wall_s"]
                           / max(a["wall_s"], 1e-9)}
        print(f"[pipeline/overlap] {n_batches} batches: sync "
              f"{s['wall_s'] * 1e3:.0f} ms ({s['blocked_waits']} blocked "
              f"waits) | async {a['wall_s'] * 1e3:.0f} ms "
              f"({a['blocked_waits']} blocked waits) | "
              f"{out['pipeline']['overlap_speedup']:.2f}x")

        # ---- 2. deterministic window list through RecordFile
        f = RecordFile(fs, "/data/epochs/epoch-00000", block_tokens * 4)
        window = 4
        per_batch = base_cfg.global_batch
        n_windows = n_batches // window
        windows = [[(w * window * per_batch + i * per_batch, per_batch)
                    for i in range(window)] for w in range(n_windows)]

        def consume(raws):
            time.sleep(compute_s)
            return sum(len(r) for r in raws)

        before = fs.stats.snapshot()
        sync_bytes = sum(consume(f.read_record_runs(w)) for w in windows)
        mid = fs.stats.snapshot()
        # async issue-ahead: window W+1 in flight while W is consumed
        futs = f.read_record_runs_async(windows[0])
        async_bytes = 0
        for w in windows[1:]:
            nxt = f.read_record_runs_async(w)
            async_bytes += consume(futs.result())
            futs = nxt
        async_bytes += consume(futs.result())
        after = fs.stats.snapshot()
        # hot re-read: same windows again → the plan cache must serve them
        rehit = [f.read_record_runs_async(w).result() for w in windows]
        final = fs.stats.snapshot()

        assert async_bytes == sync_bytes
        sync_rounds = mid["fetch_batches"] - before["fetch_batches"]
        async_rounds = after["fetch_batches"] - mid["fetch_batches"]
        sync_blocked = mid["blocked_waits"] - before["blocked_waits"]
        async_blocked = after["blocked_waits"] - mid["blocked_waits"]
        cache_hits = final["plan_cache_hits"] - after["plan_cache_hits"]
        assert async_rounds <= sync_rounds, (
            f"async prefetch must not add storage rounds: "
            f"{async_rounds} vs {sync_rounds}")
        assert async_blocked < sync_blocked, (
            f"issue-ahead must block strictly less: "
            f"{async_blocked} vs {sync_blocked}")
        assert cache_hits > 0, "hot re-read must hit the plan cache"
        assert all(got == f.read_record_runs(w)
                   for got, w in zip(rehit, windows)), \
            "plan-cache hits must serve the identical bytes (no staleness)"
        f.close()
        out["windows"] = {
            "n_windows": n_windows,
            "sync": {"fetch_batches": sync_rounds,
                     "blocked_waits": sync_blocked},
            "async": {"fetch_batches": async_rounds,
                      "blocked_waits": async_blocked},
            "reread_plan_cache_hits": cache_hits,
        }
        print(f"[pipeline/overlap] {n_windows} windows: rounds "
              f"{sync_rounds}->{async_rounds} | blocked waits "
              f"{sync_blocked}->{async_blocked} | re-read plan-cache "
              f"hits {cache_hits}")
        out["io_runtime"] = cluster.total_stats()["io_runtime"]
    save_result("pipeline_overlap", out)
    return out


if __name__ == "__main__":
    _scale = Scale.of(sys.argv[1] if len(sys.argv) > 1 else "quick")
    _scenario = sys.argv[2] if len(sys.argv) > 2 else "pipeline"
    if _scenario not in ("pipeline", "overlap", "all"):
        raise ValueError(f"unknown scenario {_scenario!r}: "
                         "choose pipeline, overlap, or all")
    if _scenario in ("pipeline", "all"):
        run(_scale)
    if _scenario in ("overlap", "all"):
        run_overlap(_scale)
