"""Beyond-paper: the WTF substrate under the training stack.

  * zero-copy global shuffle of a token dataset (epoch files) vs a naive
    read-everything/rewrite shuffle;
  * incremental checkpointing (slice sharing) and zero-copy RESHARD
    (256→512-host style re-partition) vs full rewrite.
"""
from __future__ import annotations

import time

import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data.records import RecordFile, write_token_shard
from repro.data.shuffle import shuffle_epoch

from .common import Scale, fmt_bytes, save_result, wtf_cluster, wtf_io


def run(scale: Scale) -> dict:
    out = {}
    block_tokens = 512
    n_tokens = min(scale.total_bytes // 8, 2 << 20)
    with wtf_cluster(scale) as cluster:
        fs = cluster.client()
        fs.mkdir("/data")
        rng = np.random.RandomState(0)
        spec = write_token_shard(fs, "/data/shard0",
                                 iter(rng.randint(0, 50000, n_tokens)),
                                 block_tokens)
        cluster.reset_io_stats()

        t0 = time.perf_counter()
        n_shuffled = shuffle_epoch(fs, ["/data/shard0"], "/data/epoch0",
                                   block_tokens * 4, seed=1)
        secs = time.perf_counter() - t0
        assert n_shuffled == spec.count
        io = wtf_io(cluster)
        out["shuffle"] = {
            "records": spec.count, "wall_s": secs,
            "data_bytes_moved": io["bytes_read"] + io["bytes_written"],
            "naive_bytes": 2 * spec.count * spec.record_bytes,
        }
        print(f"[pipeline] zero-copy shuffle of {spec.count} records: "
              f"{fmt_bytes(out['shuffle']['data_bytes_moved'])} moved "
              f"(naive: {fmt_bytes(out['shuffle']['naive_bytes'])}), "
              f"{secs:.2f}s")

        # ---- checkpoint: save, incremental save, reshard.  All four
        # "hosts" write their shards; host 0 commits last (the barrier).
        mgr = CheckpointManager(fs, "/ckpt")
        tree = {"w": np.random.RandomState(1).rand(256, 1024),
                "b": np.random.RandomState(2).rand(1024),
                "frozen": np.random.RandomState(3).rand(512, 512)}

        def save_all_hosts(step, t, prev=None):
            stats = None
            for h in (1, 2, 3, 0):
                s = mgr.save(step, t, host_id=h, num_hosts=4,
                             prev_step=prev)
                if h == 0:
                    stats = s
                else:
                    stats = s if stats is None else {
                        k: stats.get(k, 0) + v for k, v in s.items()}
            return stats

        s1 = save_all_hosts(100, tree)
        tree2 = dict(tree)
        tree2["w"] = tree["w"] + 1.0          # only w changed
        s2 = save_all_hosts(200, tree2, prev=100)
        cluster.reset_io_stats()
        t0 = time.perf_counter()
        mgr.reshard(200, new_shards=8, dst_step=300)
        rs = time.perf_counter() - t0
        io = wtf_io(cluster)
        restored = mgr.restore(tree2, step=300)
        assert np.allclose(restored["w"], tree2["w"])
        out["checkpoint"] = {
            "full_save_bytes": s1["bytes_written"],
            "incremental_save_bytes": s2["bytes_written"],
            "incremental_shared_bytes": s2["bytes_shared"],
            "reshard_data_bytes": io["bytes_read"] + io["bytes_written"],
            "reshard_wall_s": rs,
        }
        print(f"[pipeline] ckpt full={fmt_bytes(s1['bytes_written'])} "
              f"incr={fmt_bytes(s2['bytes_written'])} "
              f"(shared {fmt_bytes(s2['bytes_shared'])}); 4→8-host "
              f"reshard moved {fmt_bytes(out['checkpoint']['reshard_data_bytes'])} "
              f"in {rs:.2f}s")
    save_result("pipeline_bench", out)
    return out


if __name__ == "__main__":
    run(Scale.of("quick"))
